"""External sampling profiler — host plane (paper §III-D "profiler").

The paper attaches a stand-alone helper *process* to gem5 via Linux
``perf_event`` and periodically captures call-chains without instrumenting the
target. The container-feasible JAX analogue keeps the same contract — the
profiled code is never modified and never calls into the profiler — by running
a dedicated helper *thread* that:

* every ``period`` seconds snapshots **every** Python thread's stack via
  ``sys._current_frames()`` (the target threads are fully unaware; CPython
  publishes the frames, the helper walks them),
* resolves "symbols" from code objects and classifies each frame by origin
  (``repro``/``jax``/``numpy``/``py``), mirroring the paper's ELF symbol
  resolution + its observation that ~20 frames of a typical gem5 stack are
  pybind11 bookkeeping — here the analogous noise is jax dispatch/tracing,
* merges each sample into a :class:`~repro.core.calltree.CallTree` on the fly,
* records a ``(t, depth)`` timeline (paper Fig. 2),
* optionally samples ``/proc/self`` cpu/rss (the paper's host-resource plane).

A true out-of-process backend (py-spy / perf with ``PERF_COUNT_SW_CPU_CLOCK``)
drops in by replacing :meth:`StackSampler._capture`; on a TPU pod each host
runs its own sampler and the per-host trees are merged with
``CallTree.merge`` at rendezvous (see ``launch/launcher.py``).
"""

from __future__ import annotations

import os
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

from .calltree import SAMPLES, CallTree

# Default matches the paper (§V-E): 0.5 s balances detail vs overhead.
DEFAULT_PERIOD_S = 0.5


def classify_frame(filename: str) -> str:
    """Coarse symbol "origin" classification (paper: gem5 vs pybind vs libc)."""
    if "/repro/" in filename or filename.endswith("repro"):
        return "repro"
    if "/jax/" in filename or "/jaxlib/" in filename:
        return "jax"
    if "/numpy/" in filename:
        return "numpy"
    return "py"


def frame_symbol(frame) -> str:
    code = frame.f_code
    origin = classify_frame(code.co_filename)
    return f"{origin}::{code.co_name}"


@dataclass
class SamplerConfig:
    period_s: float = DEFAULT_PERIOD_S
    max_depth: int = 256
    # Collapse consecutive frames from these origins into one node — the
    # paper's answer to "20 pybind frames bury the interesting ones".
    collapse_origins: tuple[str, ...] = ()
    record_timeline: bool = True
    record_rusage: bool = True


@dataclass
class TimelinePoint:
    t: float
    depth: int
    thread: str


@dataclass
class RusagePoint:
    t: float
    cpu_s: float
    rss_bytes: int


class StackSampler:
    """Sampling-based, non-intrusive profiler for the host runtime."""

    def __init__(self, config: Optional[SamplerConfig] = None):
        self.config = config or SamplerConfig()
        self.tree = CallTree()
        self.timeline: list[TimelinePoint] = []
        self.rusage: list[RusagePoint] = []
        self.n_samples = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.monotonic()
        self._psutil_proc = None
        if self.config.record_rusage:
            try:
                import psutil

                self._psutil_proc = psutil.Process(os.getpid())
            except Exception:  # pragma: no cover - psutil is optional
                self._psutil_proc = None

    # -- capture -----------------------------------------------------------------

    def _stack_of(self, frame) -> list[str]:
        rev: list[str] = []
        depth = 0
        while frame is not None and depth < self.config.max_depth:
            rev.append(frame_symbol(frame))
            frame = frame.f_back
            depth += 1
        rev.reverse()  # root -> leaf
        if self.config.collapse_origins:
            collapsed: list[str] = []
            for sym in rev:
                origin = sym.split("::", 1)[0]
                if origin in self.config.collapse_origins and collapsed and collapsed[-1] == f"{origin}::*":
                    continue
                collapsed.append(f"{origin}::*" if origin in self.config.collapse_origins else sym)
            rev = collapsed
        return rev

    def _capture(self) -> None:
        me = threading.get_ident()
        names = {t.ident: t.name for t in threading.enumerate()}
        now = time.monotonic() - self._t0
        frames = sys._current_frames()
        with self._lock:
            for ident, frame in frames.items():
                # Profiler infrastructure lives "outside the cgroup": neither
                # the helper itself nor watchdog/report threads are profiled.
                if ident == me or names.get(ident, "").startswith("repro-"):
                    continue
                stack = self._stack_of(frame)
                tname = names.get(ident, f"tid{ident}")
                self.tree.add_stack([f"thread::{tname}"] + stack)
                if self.config.record_timeline:
                    self.timeline.append(TimelinePoint(now, len(stack), tname))
            self.n_samples += 1
            if self._psutil_proc is not None:
                try:
                    cpu = self._psutil_proc.cpu_times()
                    rss = self._psutil_proc.memory_info().rss
                    self.rusage.append(RusagePoint(now, cpu.user + cpu.system, rss))
                except Exception:
                    pass

    def _run(self) -> None:
        while not self._stop.wait(self.config.period_s):
            try:
                self._capture()
            except Exception:
                # The profiler must never take down the run it observes.
                pass

    # -- lifecycle ------------------------------------------------------------------

    def start(self) -> "StackSampler":
        if self._thread is not None:
            raise RuntimeError("sampler already started")
        self._t0 = time.monotonic()
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, name="repro-prof-helper", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> CallTree:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=5.0)
            self._thread = None
        return self.snapshot()

    def __enter__(self) -> "StackSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- access -----------------------------------------------------------------------

    def snapshot(self) -> CallTree:
        """Thread-safe copy of the merged tree (detector windows use this)."""
        with self._lock:
            return self.tree.copy()

    def sample_now(self) -> None:
        """Force one synchronous sample (used by tests and the detector loop)."""
        self._capture()

    def depth_trace(self) -> list[tuple[float, int]]:
        with self._lock:
            return [(p.t, p.depth) for p in self.timeline]
