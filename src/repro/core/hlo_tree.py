"""Device-plane call-tree: component attribution of the compiled XLA program.

The paper's insight is that the simulator's call-stack reflects the simulated
architecture. On TPU the "simulated architecture" is the compiled XLA program
executing the model: the host cannot sample it, but every HLO instruction
carries ``metadata={op_name="jit(step)/<module>/<submodule>/<op>"}`` — the
``jax.named_scope`` call-path under which it was traced. That path *is* the
call-stack of the compiled program, and we merge it into the very same
:class:`~repro.core.calltree.CallTree`, with cost-model metrics as counters:

* ``flops``      — matmul/conv FLOPs (2 * prod(out_dims) * prod(contract_dims));
* ``bytes``      — memory traffic at fusion boundaries (operands + result; a
                   post-fusion instruction is one kernel, so its boundary
                   traffic approximates HBM traffic);
* ``coll_bytes`` — operand bytes of every collective instruction
                   (all-gather / all-reduce / reduce-scatter / all-to-all /
                   collective-permute), the §Roofline collective term;
* ``ops``        — instruction count (dominance denominators for the detector).

``while`` bodies (``lax.scan`` over layers) are multiplied by their
``known_trip_count`` from ``backend_config``, so a scanned 94-layer stack is
attributed at full cost. All shapes in post-SPMD HLO are per-device shard
shapes, so every metric here is **per device** — consistent with
``compiled.cost_analysis()``.
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field

from .calltree import CallTree

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# Fusion-optimistic traffic model: only ops that stay HBM-visible on TPU are
# charged bytes. Standalone elementwise/broadcast/reshape ops fuse into their
# producers/consumers on TPU (the CPU backend leaves many unfused, which would
# wildly overstate the memory term), so they are NOT in this set.
_TRAFFIC_OPS = {
    "dot",
    "convolution",
    "fusion",
    "custom-call",
    "copy",
    "copy-start",
    "transpose",
    "reduce",
    "reduce-window",
    "sort",
    "gather",
    "scatter",
    "dynamic-slice",
    "dynamic-update-slice",
    "pad",
    "concatenate",
    "slice",
    "select-and-scatter",
    "cholesky",
    "triangular-solve",
    "fft",
    *COLLECTIVE_OPS,
}

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# NOTE: tuple types embed `/*index=N*/` comments (with '=') every 5 elements,
# so the tuple alternative must only exclude parens, not '='.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<opcode>[\w\-]+)\((?P<rest>.*)$"
)
_COMP_HEADER_RE = re.compile(r"^(?P<entry>ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_METADATA_RE = re.compile(r'op_name="([^"]+)"')
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


@dataclass
class HloOp:
    name: str
    opcode: str
    shapes: list[tuple[str, tuple[int, ...]]]  # result (flattened if tuple)
    operands: list[str]
    op_name: str | None
    trip_count: int = 1
    called: list[str] = field(default_factory=list)
    attrs: str = ""

    def result_bytes(self) -> int:
        total = 0
        for dtype, dims in self.shapes:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES.get(dtype, 4)
        return total


@dataclass
class HloComputation:
    name: str
    ops: dict[str, HloOp] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = tuple(int(x) for x in m.group(2).split(",") if x != "")
        out.append((m.group(1), dims))
    return out


def parse_hlo_module(text: str) -> dict[str, HloComputation]:
    """Parse post-optimization HLO text into computations with a symbol table."""
    comps: dict[str, HloComputation] = {}
    current: HloComputation | None = None
    entry_name: str | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if current is None:
            m = _COMP_HEADER_RE.match(line)
            if m and not line.startswith("HloModule"):
                current = HloComputation(m.group("name"))
                if m.group("entry"):
                    entry_name = current.name
            continue
        if stripped == "}":
            comps[current.name] = current
            current = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        rest = m.group("rest")
        # Operand list ends at the first unnested ')'.
        depth = 0
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        operand_str, attrs = rest[:end], rest[end + 1:]
        if "%" in operand_str:
            # Typed operand lists (`dot(f32[8,16]{1,0} %Arg_0.1, ...)`): only
            # %-prefixed tokens are operand names; the rest is dtype/layout
            # noise that would otherwise shadow operand 0 and zero out the
            # dot-flops / traffic attribution.
            operands = re.findall(r"%([\w.\-]+)", operand_str)
        else:
            operands = re.findall(r"([\w.\-]+)", operand_str)
            # Keep only tokens that look like op names (filter literals like "0").
            operands = [o for o in operands if not re.fullmatch(r"[0-9.eE+\-]+", o)]
        mmeta = _METADATA_RE.search(attrs)
        mtrip = _TRIP_RE.search(attrs)
        called = _CALLS_RE.findall(attrs)
        op = HloOp(
            name=m.group("name"),
            opcode=m.group("opcode"),
            shapes=_parse_shapes(m.group("type")),
            operands=operands,
            op_name=mmeta.group(1) if mmeta else None,
            trip_count=int(mtrip.group(1)) if mtrip else 1,
            called=called,
            attrs=attrs,
        )
        current.ops[op.name] = op
        current.order.append(op.name)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(op: HloOp, comp: HloComputation) -> float:
    """2 * prod(output dims) * prod(lhs contracting dim sizes)."""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if not m or not op.operands:
        return 0.0
    lhs = comp.ops.get(op.operands[0])
    if lhs is None or not lhs.shapes:
        return 0.0
    lhs_dims = lhs.shapes[0][1]
    contract = 1
    for idx in (int(x) for x in m.group(1).split(",") if x):
        if idx < len(lhs_dims):
            contract *= lhs_dims[idx]
    out_elems = 1
    for _, dims in op.shapes[:1]:
        for d in dims:
            out_elems *= d
    return 2.0 * out_elems * contract


def _conv_flops(op: HloOp, comp: HloComputation) -> float:
    if not op.operands or len(op.operands) < 2:
        return 0.0
    rhs = comp.ops.get(op.operands[1])
    if rhs is None or not rhs.shapes:
        return 0.0
    kernel_elems = 1
    for d in rhs.shapes[0][1]:
        kernel_elems *= d
    out_elems = 1
    for _, dims in op.shapes[:1]:
        for d in dims:
            out_elems *= d
    # 2 * out_elems * (kernel / out_features): approximation adequate for stubs.
    return 2.0 * out_elems * kernel_elems


def build_device_tree(
    hlo_text: str,
    *,
    entry: str | None = None,
    step_name: str | None = None,
) -> CallTree:
    """Build the device-plane CallTree from compiled HLO text."""
    comps = parse_hlo_module(hlo_text)
    if not comps:
        return CallTree()
    if entry is None:
        if "__entry__" in comps:
            entry = comps["__entry__"].name
        else:
            # Fallback: the computation no other computation calls.
            called_names = {c for comp in comps.values() for op in comp.ops.values() for c in op.called}
            candidates = [n for n in comps if n != "__entry__" and n not in called_names]
            entry = candidates[-1] if candidates else next(iter(comps))
    tree = CallTree()

    def op_path(op: HloOp) -> list[str]:
        if op.op_name:
            frames = [f for f in op.op_name.split("/") if f]
            if step_name and frames and frames[0].startswith("jit("):
                frames[0] = step_name
            return frames + [op.opcode]
        return ["<unattributed>", op.opcode]

    def visit(comp_name: str, multiplier: float, seen: tuple[str, ...]) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        for name in comp.order:
            op = comp.ops[name]
            metrics = {"ops": 1.0 * multiplier}
            if op.opcode == "dot":
                metrics["flops"] = _dot_flops(op, comp) * multiplier
            elif op.opcode == "convolution":
                metrics["flops"] = _conv_flops(op, comp) * multiplier
            if op.opcode in _TRAFFIC_OPS:
                # In-place semantics for indexed ops (TPU aliases while-loop
                # buffers; charging the full operand per iteration would be a
                # CPU-backend artifact): slice/gather move ~2x the slice;
                # dynamic-update-slice/scatter move ~2x the update operand;
                # in-loop copies are CPU aliasing artifacts and are skipped.
                if op.opcode in ("dynamic-slice", "gather"):
                    metrics["bytes"] = 2 * op.result_bytes() * multiplier
                elif op.opcode in ("dynamic-update-slice", "scatter"):
                    upd_idx = 1 if op.opcode == "dynamic-update-slice" else 2
                    upd = comp.ops.get(op.operands[upd_idx]) if len(op.operands) > upd_idx else None
                    moved = upd.result_bytes() if upd is not None else op.result_bytes()
                    metrics["bytes"] = 2 * moved * multiplier
                elif op.opcode == "copy":
                    if multiplier <= 1:
                        metrics["bytes"] = 2 * op.result_bytes() * multiplier
                else:
                    operand_bytes = 0
                    for o in op.operands:
                        src = comp.ops.get(o)
                        if src is not None:
                            operand_bytes += src.result_bytes()
                    metrics["bytes"] = (op.result_bytes() + operand_bytes) * multiplier
            if op.opcode in COLLECTIVE_OPS:
                operand_bytes = 0
                for o in op.operands:
                    src = comp.ops.get(o)
                    if src is not None:
                        operand_bytes += src.result_bytes()
                metrics["coll_bytes"] = operand_bytes * multiplier
                metrics[f"coll_bytes::{op.opcode}"] = operand_bytes * multiplier
            tree.add_stack(op_path(op), metrics)
            if op.opcode == "while":
                body = _BODY_RE.search(op.attrs)
                if body:
                    visit(body.group(1), multiplier * op.trip_count, seen + (comp_name,))
            elif op.opcode in ("call", "conditional", "async-start"):
                for c in op.called:
                    visit(c, multiplier, seen + (comp_name,))
            # fusions are NOT descended into: one fusion == one kernel, and its
            # boundary traffic is already counted above.
    visit(entry, 1.0, ())
    return tree


def collective_summary(tree: CallTree) -> dict[str, float]:
    """Total collective bytes per collective kind + overall (per device)."""
    out: dict[str, float] = {"total": tree.total("coll_bytes")}
    for k, v in tree.root.metrics.items():
        if k.startswith("coll_bytes::"):
            out[k.split("::", 1)[1]] = v
    return out


def tree_from_compiled(compiled, **kw) -> CallTree:
    """Convenience: build the device tree straight from a jax compiled object."""
    return build_device_tree(compiled.as_text(), **kw)


DEVICE_TREE_SCHEMA = "repro-device-tree/v1"


def save_device_tree(tree: CallTree, path: str, *, meta: dict | None = None) -> None:
    """Persist a device-plane tree as a versioned ``device_tree.json`` artifact.

    The write is atomic (tmp + rename): daemons and servers discover this file
    lazily beside a profile that is still being written.  JSON float encoding
    is ``repr``-based, so every metric value — including ``while``
    trip-count-multiplied flops and per-kind ``coll_bytes::*`` counters —
    roundtrips bit-exactly through :func:`load_device_tree`.
    """
    doc: dict = {"schema": DEVICE_TREE_SCHEMA, "root": tree.root.to_dict()}
    if meta:
        doc["meta"] = dict(meta)
    tmp = f"{path}.tmp.{id(doc)}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)


def load_device_tree(path: str) -> CallTree:
    """Load a ``device_tree.json`` (versioned envelope or legacy bare root)."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        raise ValueError(f"{path}: not a device tree artifact")
    if "schema" in doc:
        if doc["schema"] != DEVICE_TREE_SCHEMA:
            raise ValueError(f"{path}: unsupported device tree schema {doc['schema']!r}")
        root = doc.get("root")
    else:  # legacy: a bare CallTree.to_json() dump
        root = doc
    if not isinstance(root, dict) or "name" not in root:
        raise ValueError(f"{path}: device tree artifact has no root node")
    from .calltree import CallNode

    return CallTree(CallNode.from_dict(root))
