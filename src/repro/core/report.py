"""Interactive HTML/JSON report export (paper §III-D "call-stack analyzer").

The paper exports the merged call tree as an interactive HTML/JSON report with
expand/collapse navigation. We emit a dependency-free standalone HTML page
(nested ``<details>`` elements + share bars) plus the raw JSON tree, and a
parser-config mechanism mirroring the artifact's 125 exploration configs:
each :class:`ViewConfig` selects a root, a fold level, white/blacklists and a
metric, and renders either HTML or CSV.
"""

from __future__ import annotations

import html
import json
import os
from dataclasses import dataclass

from .calltree import SAMPLES, CallNode, CallTree

_PAGE = """<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>{title}</title>
<style>
 body {{ font-family: ui-monospace, monospace; background:#111; color:#ddd; margin:1.5em; }}
 details {{ margin-left: 1.2em; border-left: 1px solid #333; padding-left: .4em; }}
 summary {{ cursor: pointer; white-space: nowrap; }}
 .bar {{ display:inline-block; height:.7em; background:#4a8; margin-right:.5em; vertical-align:middle; }}
 .pct {{ color:#8cf; }} .self {{ color:#fa6; }} .name {{ color:#eee; }}
 .controls {{ margin-bottom:1em; }}
 button {{ background:#222; color:#ddd; border:1px solid #444; padding:.3em .8em; cursor:pointer; }}
</style></head>
<body>
<h2>{title}</h2>
<div class="controls">
 <button onclick="document.querySelectorAll('details').forEach(d=>d.open=true)">expand all</button>
 <button onclick="document.querySelectorAll('details').forEach(d=>d.open=false)">collapse all</button>
 metric: <b>{metric}</b> &nbsp; total: <b>{total:.6g}</b>
</div>
{body}
<script type="application/json" id="calltree-json">{json_blob}</script>
</body></html>
"""


def _node_html(node: CallNode, total: float, metric: str, depth: int, max_depth: int) -> str:
    val = node.metrics.get(metric, 0.0)
    share = val / total if total else 0.0
    selfv = node.self_metrics.get(metric, 0.0)
    bar = f'<span class="bar" style="width:{max(1, int(share * 240))}px"></span>'
    label = (
        f'{bar}<span class="pct">{share:6.2%}</span> '
        f'<span class="name">{html.escape(node.name)}</span> '
        f'<span class="self">(self {selfv:.4g})</span>'
    )
    kids = sorted(node.children.values(), key=lambda c: -c.metrics.get(metric, 0.0))
    if not kids or (max_depth >= 0 and depth >= max_depth):
        return f"<div>&nbsp;&nbsp;{label}</div>\n"
    inner = "".join(_node_html(c, total, metric, depth + 1, max_depth) for c in kids)
    return f"<details{' open' if depth < 2 else ''}><summary>{label}</summary>\n{inner}</details>\n"


def render_html(tree: CallTree, title: str = "repro call-tree", metric: str = SAMPLES, max_depth: int = -1) -> str:
    total = max(tree.total(metric), 1e-12)
    body = "".join(
        _node_html(c, total, metric, 0, max_depth)
        for c in sorted(tree.root.children.values(), key=lambda c: -c.metrics.get(metric, 0.0))
    )
    # The JSON blob lives inside a <script> element: a frame named
    # "</script>" (or anything containing "</") would terminate the element
    # early and spill the rest of the tree into the page as markup — where
    # the browser swallows anything tag-shaped (e.g. "<module>").  "<\/" is
    # the identical JSON string, and can never close the script element.
    return _PAGE.format(
        title=html.escape(title),
        metric=html.escape(metric),
        total=tree.total(metric),
        body=body,
        json_blob=tree.to_json().replace("</", "<\\/"),
    )


def write_report(tree: CallTree, out_dir: str, name: str, metric: str = SAMPLES) -> dict[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    paths = {
        "html": os.path.join(out_dir, f"{name}.html"),
        "json": os.path.join(out_dir, f"{name}.json"),
    }
    with open(paths["html"], "w") as f:
        f.write(render_html(tree, title=name, metric=metric))
    with open(paths["json"], "w") as f:
        f.write(tree.to_json(indent=1))
    return paths


# -- cross-run differential analysis ----------------------------------------


def name_shares(tree: CallTree, metric: str = SAMPLES, self_only: bool = True) -> dict[str, float]:
    """Per-function-name share vector, normalized to sum to 1.

    ``self_only=True`` (default for regression checks) attributes each sample
    to the function it *ended* in, which is the sharp signal: an injected hot
    loop shows up as its own self-share, not smeared over its whole ancestry.
    """
    out: dict[str, float] = {}
    for _path, node in tree.root.walk():
        if node is tree.root:
            continue
        src = node.self_metrics if self_only else node.metrics
        v = src.get(metric, 0.0)
        if v:
            out[node.name] = out.get(node.name, 0.0) + v
    total = sum(out.values())
    if total <= 0:
        return {}
    return {k: v / total for k, v in out.items()}


def diff_rows(
    a: CallTree,
    b: CallTree,
    metric: str = SAMPLES,
    self_only: bool = False,
) -> list[tuple[tuple[str, ...], float, float, float]]:
    """Per-call-site share deltas between two trees (the cross-run diff).

    Returns ``(path, share_a, share_b, share_b - share_a)`` over the union of
    call-site paths, sorted by descending ``|delta|`` — "did this change make
    the hot path slower" answered per node.
    """
    sa = a.shares(metric, self_only=self_only)
    sb = b.shares(metric, self_only=self_only)
    rows = []
    for path in set(sa) | set(sb):
        va, vb = sa.get(path, 0.0), sb.get(path, 0.0)
        rows.append((path, va, vb, vb - va))
    rows.sort(key=lambda r: (-abs(r[3]), r[0]))
    return rows


def render_diff(
    a: CallTree,
    b: CallTree,
    metric: str = SAMPLES,
    *,
    label_a: str = "a",
    label_b: str = "b",
    min_delta: float = 0.002,
    max_rows: int = 40,
    self_only: bool = False,
) -> str:
    """Text rendering of a cross-run tree diff (per-node share deltas)."""
    from .detector import share_distance

    rows = diff_rows(a, b, metric, self_only=self_only)
    dist = share_distance(name_shares(a, metric), name_shares(b, metric))
    lines = [
        f"# diff metric={metric} {label_a}: total={a.total(metric):.6g} "
        f"{label_b}: total={b.total(metric):.6g} share_distance={dist:.4f}",
        f"{'delta':>8}  {label_a:>7}  {label_b:>7}  path",
    ]
    shown = 0
    for path, va, vb, d in rows:
        if abs(d) < min_delta:
            continue
        lines.append(f"{d:+8.2%}  {va:7.2%}  {vb:7.2%}  {'/'.join(path)}")
        shown += 1
        if shown >= max_rows:
            lines.append(f"# ... {sum(1 for r in rows if abs(r[3]) >= min_delta) - shown} more rows")
            break
    if shown == 0:
        lines.append("# trees are share-identical at this resolution")
    return "\n".join(lines)


def share_regressions(
    baseline: CallTree,
    current: CallTree,
    metric: str = SAMPLES,
    tolerance: float = 0.05,
    self_only: bool = True,
) -> list[tuple[str, float, float, float]]:
    """Functions whose share *grew* beyond ``tolerance`` vs the baseline.

    The ``profilerd check`` gate: only increases count (a function losing
    share is someone else's increase), compared on the per-name share vector
    so run length cancels out.  Returns ``(name, base, cur, delta)`` sorted
    by descending delta.
    """
    base = name_shares(baseline, metric, self_only=self_only)
    cur = name_shares(current, metric, self_only=self_only)
    out = []
    for name in set(base) | set(cur):
        d = cur.get(name, 0.0) - base.get(name, 0.0)
        if d > tolerance:
            out.append((name, base.get(name, 0.0), cur.get(name, 0.0), d))
    out.sort(key=lambda r: -r[3])
    return out


#: Row prepended to a view CSV whose ``root=`` matched no node.
NO_MATCH_MARKER = "# no match for root="

#: Row prepended to a view CSV whose filters/whitelist removed every row
#: (the root *did* match — distinct from :data:`NO_MATCH_MARKER`).
EMPTY_VIEW_MARKER = "# empty view: filters removed every row"


def min_share_marker(min_share: float) -> str:
    """Marker row for a ``min_share`` threshold that pruned every row —
    shared by :meth:`ViewConfig.to_csv` and ``repro.core.export.prepare_view``
    so the CSV body and the CLI/server verdicts can never drift apart."""
    return f"# empty view: min_share={min_share:g} pruned every row"


@dataclass
class ViewConfig:
    """One exploration config (artifact §G): root, fold level, filters."""

    name: str = "view"
    root: str | None = None  # zoom selector (substring of a node name)
    level: int = -1  # -1 expands to leaves, n truncates (artifact semantics)
    metric: str = SAMPLES
    whitelist: list[str] | None = None
    blacklist: list[str] | None = None
    min_share: float = 0.0

    def apply(self, tree: CallTree) -> CallTree:
        t = tree
        if self.root:
            t = t.zoom(lambda n, r=self.root: r in n)
        if self.whitelist or self.blacklist:
            t = t.filtered(self.whitelist, self.blacklist)
        if self.level >= 0:
            t = t.levels(self.level)
        return t

    def matches(self, tree: CallTree) -> bool:
        """False when ``root=`` selected nothing — the view is vacuously empty.

        An empty zoom is indistinguishable from "this run genuinely spent
        nothing there" in the output rows, so consumers (the ``profilerd
        export`` CLI, CI scripts) must be able to tell the difference and
        fail loudly instead of shipping an empty CSV.
        """
        if not self.root:
            return True
        return bool(tree.zoom(lambda n, r=self.root: r in n).root.children)

    def empty_marker(self, tree: CallTree) -> str | None:
        """The marker row this view's emptiness deserves, or ``None``.

        One source of truth for :meth:`to_csv` and the ``profilerd export``
        exit code: "root selected nothing" and "root matched but the
        white/blacklist removed every row" are different operator errors and
        get different markers.  (level=0 folding everything into the root is
        not empty for CSV — the header total says it all — and an empty
        input tree is the caller's business.)
        """
        if self.root and not self.matches(tree):
            return f"{NO_MATCH_MARKER}{self.root}"
        if (self.whitelist or self.blacklist) and tree.root.children:
            # Judge the filters *before* the level fold: level=0 collapsing a
            # perfectly matching view into the root is not "filters removed
            # every row".
            t = tree
            if self.root:
                t = t.zoom(lambda n, r=self.root: r in n)
            if not t.filtered(self.whitelist, self.blacklist).root.children:
                return EMPTY_VIEW_MARKER
        return None

    def to_csv(self, tree: CallTree) -> str:
        t = self.apply(tree)
        total = max(t.total(self.metric), 1e-12)
        rows = [f"# view={self.name} metric={self.metric} total={total:.6g}", "path,value,share"]
        if not t.root.children:
            marker = self.empty_marker(tree)
            if marker is not None:
                rows.append(marker)
                return "\n".join(rows)
        shown = 0
        for path, node in t.root.walk():
            if node is t.root:
                continue
            v = node.metrics.get(self.metric, 0.0)
            if v / total >= self.min_share:
                rows.append(f"{'/'.join(path[1:])},{v:.6g},{v / total:.4f}")
                shown += 1
        if shown == 0 and self.min_share > 0 and t.root.children:
            # Same contract as the no-match markers: a threshold that prunes
            # every row must say so, not ship a header-only table.
            rows.append(min_share_marker(self.min_share))
        return "\n".join(rows)


def breakdown(tree: CallTree, level: int = 1, metric: str = SAMPLES, min_share: float = 0.005) -> list[tuple[str, float]]:
    """Top-level share table — what the paper's stacked-bar figures plot."""
    t = tree.levels(level)
    total = max(t.total(metric), 1e-12)
    out = []

    def rec(node: CallNode, prefix: str) -> None:
        for c in sorted(node.children.values(), key=lambda c: -c.metrics.get(metric, 0.0)):
            share = c.metrics.get(metric, 0.0) / total
            if share >= min_share:
                out.append((f"{prefix}{c.name}", share))
                rec(c, f"{prefix}{c.name}/")

    rec(t.root, "")
    return out


_VIEW_EXT = {"csv": "csv", "folded": "folded", "speedscope": "speedscope.json", "html": "html", "json": "json"}


def save_views(
    tree: CallTree,
    configs: list[ViewConfig],
    out_dir: str,
    formats: tuple[str, ...] = ("csv",),
) -> list[str]:
    """Write every view in every requested format (default: CSV, as before).

    Non-CSV formats route through :func:`repro.core.export.export_tree`, so
    ``formats=("csv", "folded", "html")`` turns the whole view library into
    flamegraph-ready artifacts in one call.  A view that comes out empty
    (no-match root, filters, min_share) writes its marker row as the
    artifact body instead of a vacuously empty file — same contract as the
    CSV markers and the ``profilerd export`` exit code.
    """
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for cfg in configs:
        for fmt in formats:
            p = os.path.join(out_dir, f"{cfg.name}.{_VIEW_EXT.get(fmt, fmt)}")
            if fmt == "csv":
                payload = cfg.to_csv(tree)
            else:
                from .export import export_tree, prepare_view

                applied, metric, marker = prepare_view(tree, cfg, fmt=fmt)
                if marker is not None:
                    payload = marker + "\n"
                else:
                    payload = export_tree(applied, fmt, metric=metric, title=cfg.name)
            with open(p, "w") as f:
                f.write(payload)
            written.append(p)
    return written
