"""Interactive HTML/JSON report export (paper §III-D "call-stack analyzer").

The paper exports the merged call tree as an interactive HTML/JSON report with
expand/collapse navigation. We emit a dependency-free standalone HTML page
(nested ``<details>`` elements + share bars) plus the raw JSON tree, and a
parser-config mechanism mirroring the artifact's 125 exploration configs:
each :class:`ViewConfig` selects a root, a fold level, white/blacklists and a
metric, and renders either HTML or CSV.
"""

from __future__ import annotations

import html
import json
import os
from dataclasses import dataclass, field
from typing import Optional

from .calltree import SAMPLES, CallNode, CallTree

_PAGE = """<!DOCTYPE html>
<html><head><meta charset="utf-8"><title>{title}</title>
<style>
 body {{ font-family: ui-monospace, monospace; background:#111; color:#ddd; margin:1.5em; }}
 details {{ margin-left: 1.2em; border-left: 1px solid #333; padding-left: .4em; }}
 summary {{ cursor: pointer; white-space: nowrap; }}
 .bar {{ display:inline-block; height:.7em; background:#4a8; margin-right:.5em; vertical-align:middle; }}
 .pct {{ color:#8cf; }} .self {{ color:#fa6; }} .name {{ color:#eee; }}
 .controls {{ margin-bottom:1em; }}
 button {{ background:#222; color:#ddd; border:1px solid #444; padding:.3em .8em; cursor:pointer; }}
</style></head>
<body>
<h2>{title}</h2>
<div class="controls">
 <button onclick="document.querySelectorAll('details').forEach(d=>d.open=true)">expand all</button>
 <button onclick="document.querySelectorAll('details').forEach(d=>d.open=false)">collapse all</button>
 metric: <b>{metric}</b> &nbsp; total: <b>{total:.6g}</b>
</div>
{body}
<script type="application/json" id="calltree-json">{json_blob}</script>
</body></html>
"""


def _node_html(node: CallNode, total: float, metric: str, depth: int, max_depth: int) -> str:
    val = node.metrics.get(metric, 0.0)
    share = val / total if total else 0.0
    selfv = node.self_metrics.get(metric, 0.0)
    bar = f'<span class="bar" style="width:{max(1, int(share * 240))}px"></span>'
    label = (
        f'{bar}<span class="pct">{share:6.2%}</span> '
        f'<span class="name">{html.escape(node.name)}</span> '
        f'<span class="self">(self {selfv:.4g})</span>'
    )
    kids = sorted(node.children.values(), key=lambda c: -c.metrics.get(metric, 0.0))
    if not kids or (max_depth >= 0 and depth >= max_depth):
        return f"<div>&nbsp;&nbsp;{label}</div>\n"
    inner = "".join(_node_html(c, total, metric, depth + 1, max_depth) for c in kids)
    return f"<details{' open' if depth < 2 else ''}><summary>{label}</summary>\n{inner}</details>\n"


def render_html(tree: CallTree, title: str = "repro call-tree", metric: str = SAMPLES, max_depth: int = -1) -> str:
    total = max(tree.total(metric), 1e-12)
    body = "".join(
        _node_html(c, total, metric, 0, max_depth)
        for c in sorted(tree.root.children.values(), key=lambda c: -c.metrics.get(metric, 0.0))
    )
    return _PAGE.format(
        title=html.escape(title),
        metric=html.escape(metric),
        total=tree.total(metric),
        body=body,
        json_blob=tree.to_json(),
    )


def write_report(tree: CallTree, out_dir: str, name: str, metric: str = SAMPLES) -> dict[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    paths = {
        "html": os.path.join(out_dir, f"{name}.html"),
        "json": os.path.join(out_dir, f"{name}.json"),
    }
    with open(paths["html"], "w") as f:
        f.write(render_html(tree, title=name, metric=metric))
    with open(paths["json"], "w") as f:
        f.write(tree.to_json(indent=1))
    return paths


@dataclass
class ViewConfig:
    """One exploration config (artifact §G): root, fold level, filters."""

    name: str = "view"
    root: Optional[str] = None  # zoom selector (substring of a node name)
    level: int = -1  # -1 expands to leaves, n truncates (artifact semantics)
    metric: str = SAMPLES
    whitelist: Optional[list[str]] = None
    blacklist: Optional[list[str]] = None
    min_share: float = 0.0

    def apply(self, tree: CallTree) -> CallTree:
        t = tree
        if self.root:
            t = t.zoom(lambda n, r=self.root: r in n)
        if self.whitelist or self.blacklist:
            t = t.filtered(self.whitelist, self.blacklist)
        if self.level >= 0:
            t = t.levels(self.level)
        return t

    def to_csv(self, tree: CallTree) -> str:
        t = self.apply(tree)
        total = max(t.total(self.metric), 1e-12)
        rows = [f"# view={self.name} metric={self.metric} total={total:.6g}", "path,value,share"]
        for path, node in t.root.walk():
            if node is t.root:
                continue
            v = node.metrics.get(self.metric, 0.0)
            if v / total >= self.min_share:
                rows.append(f"{'/'.join(path[1:])},{v:.6g},{v / total:.4f}")
        return "\n".join(rows)


def breakdown(tree: CallTree, level: int = 1, metric: str = SAMPLES, min_share: float = 0.005) -> list[tuple[str, float]]:
    """Top-level share table — what the paper's stacked-bar figures plot."""
    t = tree.levels(level)
    total = max(t.total(metric), 1e-12)
    out = []

    def rec(node: CallNode, prefix: str) -> None:
        for c in sorted(node.children.values(), key=lambda c: -c.metrics.get(metric, 0.0)):
            share = c.metrics.get(metric, 0.0) / total
            if share >= min_share:
                out.append((f"{prefix}{c.name}", share))
                rec(c, f"{prefix}{c.name}/")

    rec(t.root, "")
    return out


def save_views(tree: CallTree, configs: list[ViewConfig], out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for cfg in configs:
        p = os.path.join(out_dir, f"{cfg.name}.csv")
        with open(p, "w") as f:
            f.write(cfg.to_csv(tree))
        written.append(p)
    return written
