"""Three-term roofline model over compiled dry-run artifacts (§Roofline).

    compute term    = HLO_FLOPs_per_device   / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device   / HBM_bw_per_chip
    collective term = coll_bytes_per_device  / (links_per_chip * link_bw)

``compiled.cost_analysis()`` reports **per-device** FLOPs and bytes (verified
numerically in this environment), and the device tree's ``coll_bytes`` counts
per-device operand bytes of every collective instruction, so no further
division by chip count is applied. The step-time estimate is the max of the
three terms (perfect-overlap bound); the dominant term is the §Perf target.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .calltree import CallTree
from .hlo_tree import COLLECTIVE_OPS


@dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e-class chip (task-specified constants)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_link_bw: float = 50e9  # bytes/s per link
    ici_links: int = 4  # links used by a chip in a 2D torus (2 axes x 2 dirs)
    hbm_bytes: float = 16e9  # capacity, for fit checks


V5E = HardwareSpec()


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_kind: dict[str, float] = field(default_factory=dict)
    model_flops_global: float = 0.0  # 6*N*D (dense) or 6*N_active*D (MoE)
    per_device_hbm_peak: float = 0.0  # from memory_analysis
    hw: HardwareSpec = V5E

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / (self.hw.ici_links * self.hw.ici_link_bw)

    @property
    def t_step(self) -> float:
        """Perfect-overlap lower bound on step time."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory, "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): how much compiled compute is useful.

        < 1 means remat/redundancy waste; > 1 means the HLO count missed
        something (e.g. attention FLOPs not in the 6ND napkin model).
        """
        total_hlo = self.flops_per_device * self.chips
        return self.model_flops_global / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline bound."""
        if self.t_step <= 0 or self.chips == 0:
            return 0.0
        return self.model_flops_global / (self.t_step * self.chips * self.hw.peak_flops)

    @property
    def hw_util(self) -> float:
        """Fraction of roofline the dominant resource reaches if the other two
        overlap perfectly: compute-term / step-time when compute-bound, etc."""
        if self.t_step <= 0:
            return 0.0
        return self.t_compute / self.t_step

    def fits_hbm(self) -> bool:
        return self.per_device_hbm_peak <= self.hw.hbm_bytes

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_step_s": self.t_step,
            "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "hlo_flops_per_dev": self.flops_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu,
            "hbm_peak_bytes": self.per_device_hbm_peak,
            "fits_hbm": self.fits_hbm(),
            **{f"coll_{k}": v for k, v in self.coll_by_kind.items()},
        }


def report_from_artifacts(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    cost_analysis: dict,
    device_tree: CallTree,
    memory_analysis=None,
    model_flops_global: float = 0.0,
    hw: HardwareSpec = V5E,
) -> RooflineReport:
    # XLA's cost_analysis() counts while-loop bodies ONCE (verified: its FLOPs
    # fall short of 6ND by ~the layer count for scanned stacks). The device
    # tree multiplies by known_trip_count, so take the max of both estimates
    # per term (the tree counts dot/conv FLOPs only; cost_analysis adds
    # elementwise FLOPs but misses loop trips).
    flops = max(float(cost_analysis.get("flops", 0.0)), device_tree.total("flops"))
    byts = max(float(cost_analysis.get("bytes accessed", 0.0)), device_tree.total("bytes"))
    coll = device_tree.total("coll_bytes")
    by_kind = {}
    for k in COLLECTIVE_OPS:
        v = device_tree.root.metrics.get(f"coll_bytes::{k}", 0.0)
        if v:
            by_kind[k] = v
    hbm_peak = 0.0
    if memory_analysis is not None:
        hbm_peak = float(
            getattr(memory_analysis, "argument_size_in_bytes", 0.0)
            + getattr(memory_analysis, "output_size_in_bytes", 0.0)
            + getattr(memory_analysis, "temp_size_in_bytes", 0.0)
            - getattr(memory_analysis, "alias_size_in_bytes", 0.0)
        )
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=coll,
        coll_by_kind=by_kind,
        model_flops_global=model_flops_global,
        per_device_hbm_peak=hbm_peak,
        hw=hw,
    )
