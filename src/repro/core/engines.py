"""Execution engines — the AS-CPU / TS-CPU / O3-CPU analogue (paper Fig. 1).

The paper compares three simulator fidelities for the *same* workload and
finds the cost ordering counter-intuitive (the "simple" TS-CPU is often no
faster than the detailed O3). Our framework exposes the same experiment for
the *same model*:

* ``EagerEngine``     — op-by-op dispatch (``jax.disable_jit``): the simplest
                        execution model, dominated by host bookkeeping frames,
                        exactly as AS-CPU's runtime is dominated by functional
                        Ruby plumbing rather than "architecture";
* ``BlockwiseEngine`` — one ``jit`` per layer/block, Python loop between them:
                        pays a host→device round-trip at every block boundary,
                        the busy-wait analogue of TS-CPU's lockup cache;
* ``CompiledEngine``  — a single ``jit`` (+ scan + donation): the most
                        "detailed" compilation pipeline but the fastest
                        execution, as O3 often is.

All three run the same math; the sampler profiles each and the breakdown
shows *where* the cost moved (dispatch vs compute), reproducing the paper's
Fig. 1 methodology on our substrate. Benchmarked in ``benchmarks/fig01``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from collections.abc import Callable, Sequence
from typing import Any

import jax


@dataclass
class EngineResult:
    name: str
    outputs: Any
    wall_s: float
    steps: int

    @property
    def steps_per_s(self) -> float:
        return self.steps / self.wall_s if self.wall_s > 0 else float("inf")


class Engine:
    name = "engine"

    def run_step(self, *args, **kw):  # pragma: no cover - interface
        raise NotImplementedError

    def run(self, n_steps: int, make_args: Callable[[int], tuple]) -> EngineResult:
        out = None
        t0 = time.perf_counter()
        for i in range(n_steps):
            out = self.run_step(*make_args(i))
        out = jax.block_until_ready(out)
        return EngineResult(self.name, out, time.perf_counter() - t0, n_steps)


class EagerEngine(Engine):
    """Op-by-op dispatch: every primitive is dispatched individually."""

    name = "eager"

    def __init__(self, fn: Callable):
        self.fn = fn

    def run_step(self, *args, **kw):
        with jax.disable_jit():
            return self.fn(*args, **kw)


class BlockwiseEngine(Engine):
    """jit per stage, Python loop across stages (host sync at each boundary)."""

    name = "blockwise"

    def __init__(self, stages: Sequence[Callable], sync_between: bool = True):
        self.stages = [jax.jit(s) for s in stages]
        self.sync_between = sync_between

    def run_step(self, carry, *extra):
        for stage in self.stages:
            carry = stage(carry, *extra)
            if self.sync_between:
                carry = jax.block_until_ready(carry)
        return carry


class CompiledEngine(Engine):
    """Single end-to-end jit with optional donation."""

    name = "compiled"

    def __init__(self, fn: Callable, donate_argnums: tuple[int, ...] = (), **jit_kw):
        self.fn = jax.jit(fn, donate_argnums=donate_argnums, **jit_kw)

    def run_step(self, *args, **kw):
        return self.fn(*args, **kw)


def compare_engines(
    engines: Sequence[Engine],
    n_steps: int,
    make_args: Callable[[int], tuple],
    sampler_factory: Callable[[], Any] | None = None,
) -> list[dict]:
    """Run each engine for ``n_steps`` under (optionally) a fresh sampler.

    Returns per-engine dicts with throughput and top host-plane frames —
    the data behind the Fig. 1 analogue.
    """
    rows = []
    for eng in engines:
        sampler = sampler_factory() if sampler_factory else None
        if sampler:
            sampler.start()
        res = eng.run(n_steps, make_args)
        tree = sampler.stop() if sampler else None
        row = {
            "engine": eng.name,
            "steps": res.steps,
            "wall_s": res.wall_s,
            "steps_per_s": res.steps_per_s,
        }
        if tree is not None and tree.total() > 0:
            flat = tree.flatten()
            total = tree.total()
            jax_frames = sum(v for k, v in flat.items() if k.startswith("jax::"))
            repro_frames = sum(v for k, v in flat.items() if k.startswith("repro::"))
            row["jax_frame_share"] = jax_frames / max(total, 1)
            row["repro_frame_share"] = repro_frames / max(total, 1)
            row["mean_depth"] = (
                sum(d for _, d in sampler.depth_trace()) / max(len(sampler.depth_trace()), 1)
            )
        rows.append(row)
    return rows
