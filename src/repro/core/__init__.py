"""repro.core — the paper's contribution: call-stack profiling as a first-class
framework feature (host plane + device plane + anomaly detection)."""

from .calltree import SAMPLES, CallNode, CallTree
from .detector import AnomalyEvent, DominanceDetector, Rule, StragglerDetector, WatchdogLoop
from .engines import BlockwiseEngine, CompiledEngine, EagerEngine, compare_engines
from .hlo_tree import (
    COLLECTIVE_OPS,
    build_device_tree,
    collective_summary,
    parse_hlo_module,
    tree_from_compiled,
)
from .report import ViewConfig, breakdown, render_html, save_views, write_report
from .roofline import V5E, HardwareSpec, RooflineReport, report_from_artifacts
from .sampler import DEFAULT_PERIOD_S, SamplerConfig, StackSampler

__all__ = [
    "SAMPLES",
    "CallNode",
    "CallTree",
    "AnomalyEvent",
    "DominanceDetector",
    "Rule",
    "StragglerDetector",
    "WatchdogLoop",
    "BlockwiseEngine",
    "CompiledEngine",
    "EagerEngine",
    "compare_engines",
    "COLLECTIVE_OPS",
    "build_device_tree",
    "collective_summary",
    "parse_hlo_module",
    "tree_from_compiled",
    "ViewConfig",
    "breakdown",
    "render_html",
    "save_views",
    "write_report",
    "V5E",
    "HardwareSpec",
    "RooflineReport",
    "report_from_artifacts",
    "DEFAULT_PERIOD_S",
    "SamplerConfig",
    "StackSampler",
]
