"""repro.core — the paper's contribution: call-stack profiling as a first-class
framework feature (host plane + device plane + anomaly detection).

Exports resolve lazily (PEP 562): the profiling plane (``calltree`` /
``sampler`` / ``detector`` / ``report``) is pure-Python and must stay
importable in milliseconds — the out-of-process ``repro.profilerd`` daemon
imports it on every attach — while the device plane (``engines`` /
``hlo_tree`` / ``roofline``) pulls in JAX and is only paid for on first use.
"""

from importlib import import_module

_EXPORTS = {
    # host plane (light, no jax)
    "SAMPLES": ".calltree",
    "CallNode": ".calltree",
    "CallTree": ".calltree",
    "AnomalyEvent": ".detector",
    "DominanceDetector": ".detector",
    "LIVELOCK_CLEARED": ".detector",
    "Rule": ".detector",
    "StragglerDetector": ".detector",
    "TrendDetector": ".detector",
    "TrendRule": ".detector",
    "TrendVerdict": ".detector",
    "WatchdogLoop": ".detector",
    "segment_phases": ".detector",
    "CountSealer": ".snapshot",
    "EpochMeta": ".snapshot",
    "EpochSealer": ".snapshot",
    "SnapshotError": ".snapshot",
    "TimelineReader": ".snapshot",
    "TimelineWriter": ".snapshot",
    "load_snapshot": ".snapshot",
    "save_snapshot": ".snapshot",
    "DEFAULT_PERIOD_S": ".sampler",
    "SamplerBackend": ".sampler",
    "SamplerConfig": ".sampler",
    "StackSampler": ".sampler",
    "classify_frame": ".sampler",
    "collapse_stack": ".sampler",
    "frame_symbol": ".sampler",
    "make_sampler": ".sampler",
    "ViewConfig": ".report",
    "NO_MATCH_MARKER": ".report",
    "breakdown": ".report",
    "diff_rows": ".report",
    "name_shares": ".report",
    "render_diff": ".report",
    "render_html": ".report",
    "save_views": ".report",
    "share_regressions": ".report",
    "write_report": ".report",
    "EXPORT_FORMATS": ".export",
    "build_diff_tree": ".export",
    "diff_flamegraph_html": ".export",
    "export_tree": ".export",
    "flamegraph_html": ".export",
    "from_folded": ".export",
    "to_folded": ".export",
    "to_speedscope": ".export",
    # device plane (imports jax on first access)
    "BlockwiseEngine": ".engines",
    "CompiledEngine": ".engines",
    "EagerEngine": ".engines",
    "compare_engines": ".engines",
    "COLLECTIVE_OPS": ".hlo_tree",
    "build_device_tree": ".hlo_tree",
    "collective_summary": ".hlo_tree",
    "load_device_tree": ".hlo_tree",
    "parse_hlo_module": ".hlo_tree",
    "save_device_tree": ".hlo_tree",
    "tree_from_compiled": ".hlo_tree",
    "DEVICE_TREE_FILENAME": ".planes",
    "PLANES": ".planes",
    "PlaneError": ".planes",
    "annotate_tree": ".planes",
    "dominant_term": ".planes",
    "select_plane": ".planes",
    "V5E": ".roofline",
    "HardwareSpec": ".roofline",
    "RooflineReport": ".roofline",
    "report_from_artifacts": ".roofline",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(import_module(module, __name__), name)
    globals()[name] = value  # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
