"""Unified host+device planes: roofline-annotated profiles on one CallTree.

The paper's claim is that the profiler's call-stack reflects the simulated
architecture; our two planes are the sampled Python host stack and the
compiled XLA program's HLO cost tree (``core/hlo_tree.py``).  This module is
the bridge: it grafts the device-plane cost model onto the sampled host tree
so one profile answers both "where does host time go" and "which
architectural component is the roofline bottleneck, and why".

Three coherent views over the same profile:

* ``host``   — today's sampled tree, untouched;
* ``device`` — the HLO cost tree (``flops``/``bytes``/``coll_bytes``/``ops``
               counters attributed to ``op_name`` paths);
* ``merged`` — the host tree with device-plane annotations as *ordinary*
               metric keys on each matched node (see below), so they survive
               the snapshot codec, ``CallTree.diff``, folded/speedscope
               exports, and ``share_regressions`` gating with zero special
               cases.

Matching is by node *name*, flatten-view semantics: a host frame — a
``jax.named_scope``-tagged module frame (``attention``, ``moe``), a
``pl.pallas_call`` wrapper call-site (``flash_attention``, ``rglru_scan``),
or a jit dispatch frame — matches every device node with the same normalized
name (``jit(step)`` heads normalize to ``step``), and their inclusive HLO
metrics are summed.  Unmatched host nodes inherit the sum of their children,
so thread roots and glue frames aggregate their matched descendants and the
merged root carries the full matched totals.

Annotation metric keys written onto merged-plane nodes:

* ``hlo_flops`` / ``hlo_bytes`` / ``hlo_coll_bytes`` / ``hlo_ops`` — the HLO
  subtree cost attributed to that host node;
* ``rt_compute`` / ``rt_memory`` / ``rt_collective`` — the three roofline
  term times (seconds) those costs imply on the hardware spec;
* ``roofline_occupancy`` — the node's bound time (max of its three terms) as
  a fraction of the root's roofline step time: "this component accounts for
  X% of the step's roofline bound" (root = 1.0);
* ``dominant::compute|memory|collective`` — exactly one per annotated node,
  valued at the bound time in seconds (the flamegraph's coloring key).

Pure stdlib + :mod:`repro.core.calltree` / :mod:`repro.core.roofline` — no
jax import, so the merge layer is usable by the daemon/server hot paths and
the jax-free CI jobs.
"""

from __future__ import annotations

import weakref
from collections.abc import Mapping

from .calltree import CallNode, CallTree
from .roofline import V5E, HardwareSpec

PLANES = ("host", "device", "merged", "static")

DEVICE_TREE_FILENAME = "device_tree.json"
STATIC_TREE_FILENAME = "static_tree.json"

# Device-plane counters grafted onto merged-plane host nodes (prefixed).
HLO_KEYS = ("flops", "bytes", "coll_bytes", "ops")
HLO_PREFIX = "hlo_"

ROOFLINE_TERMS = ("compute", "memory", "collective")
TERM_PREFIX = "rt_"
OCCUPANCY = "roofline_occupancy"
DOMINANT_PREFIX = "dominant::"


class PlaneError(RuntimeError):
    """A requested plane cannot be served (typically: no device artifact)."""


def missing_device_hint(profile: str | None = None) -> str:
    where = f"beside the profile ({profile})" if profile else "beside the profile"
    return (
        f"no device plane: expected a {DEVICE_TREE_FILENAME} artifact {where}. "
        f"Generate one with `python -m repro.launch.dryrun --arch <arch> --shape <shape> "
        f"--dump-tree <profile>/{DEVICE_TREE_FILENAME}` or pass --device-tree to "
        f"`profilerd attach`."
    )


def missing_static_hint(profile: str | None = None) -> str:
    where = f"beside the profile ({profile})" if profile else "beside the profile"
    return (
        f"no static plane: expected a {STATIC_TREE_FILENAME} artifact {where}. "
        f"Generate one with `python -m repro.analysis extract --out "
        f"<profile>/{STATIC_TREE_FILENAME}`."
    )


def default_metric(plane: str, metric: str | None) -> str | None:
    """Planes without ``samples`` fast-lane mass get a sensible default:
    the device tree's is ``flops``, the static call graph's is ``defs``."""
    if metric:
        return metric
    if plane == "device":
        return "flops"
    if plane == "static":
        return "defs"
    return metric


def _norm(name: str) -> str:
    """Normalize a node name for host<->device matching.

    Host frames ingested from a spool carry an origin tag (``py::attention``,
    ``native::...``) that device op paths never have; ``jit(step)`` dispatch
    heads (device plane) normalize to the jitted function's name so they match
    the host frame that called it.
    """
    _head, sep, rest = name.partition("::")
    if sep and rest:
        name = rest
    if name.startswith("jit(") and name.endswith(")"):
        return name[4:-1]
    return name


#: Cached tuple index per device tree.  Keyed by weak reference: a device
#: tree is immutable once loaded (daemon/server swap in a *new* CallTree when
#: the artifact changes), so the index is computed once per artifact, not
#: once per publish window / HTTP request.
_INDEX_CACHE: "weakref.WeakKeyDictionary[CallTree, dict[str, tuple[float, float, float, float]]]" = (
    weakref.WeakKeyDictionary()
)

_HLO_FULL_KEYS = tuple(HLO_PREFIX + k for k in HLO_KEYS)


def _device_index(device: CallTree) -> dict[str, tuple[float, float, float, float]]:
    """Flatten-view index: normalized name -> (flops, bytes, coll_bytes, ops)."""
    index = _INDEX_CACHE.get(device)
    if index is not None:
        return index
    index = {}
    for _path, node in device.root.walk():
        if node is device.root:
            continue
        key = _norm(node.name)
        m = node.metrics
        f = m.get("flops", 0.0)
        b = m.get("bytes", 0.0)
        cb = m.get("coll_bytes", 0.0)
        o = m.get("ops", 0.0)
        cur = index.get(key)
        index[key] = (f, b, cb, o) if cur is None else (cur[0] + f, cur[1] + b, cur[2] + cb, cur[3] + o)
    _INDEX_CACHE[device] = index
    return index


def device_name_index(device: CallTree) -> dict[str, dict[str, float]]:
    """Flatten-view index: normalized node name -> summed inclusive HLO metrics."""
    return {k: dict(zip(HLO_KEYS, v, strict=True)) for k, v in _device_index(device).items()}


#: Memoized ``_norm``: frame names are interned by the ingest layer, so a
#: long-lived daemon sees the same string objects window after window and
#: this degenerates to one dict hit per node.  Bounded by the number of
#: distinct frame names, like the interner itself.
_NORM_CACHE: dict[str, str] = {}


def annotate_tree(
    host: CallTree, device: CallTree, hw: HardwareSpec = V5E, *, copy: bool = True
) -> CallTree:
    """The merged plane: ``host`` with device-plane annotations.

    Annotations keep inclusive-metric semantics: a matched node carries its
    matched HLO subtree cost (floored at the sum of its children, so nesting
    stays monotone); an unmatched node carries the sum of its children.  Self
    metrics get the structural residual, so folded/speedscope exports and
    ``shares(self_only=True)`` gating stay exact.

    With ``copy=True`` (default) the host tree is left untouched and an
    annotated copy is returned — what the query plane wants, since it
    annotates shared published snapshots per request.  The daemon's seal
    path already builds a private fleet tree every epoch; it passes
    ``copy=False`` to annotate that tree in place, so the device plane's
    marginal cost per publish window is one attribution walk, not an extra
    tree copy (``annotate_overhead`` in ``BENCH_ingest.json`` holds it to
    <5 % of ingest time).

    The walk is hot-path code: per-subtree costs travel as tuples, the
    device index is cached per artifact, occupancy falls out of the same
    pass (every occupancy value is ``bound / t_step``, so bounds are
    collected in a flat list and scaled once the root total is known), and
    annotation writes go straight to the node's metric dicts — ``hlo_*``
    keys never collide with the sample fast-lane.
    """
    merged = host.copy() if copy else host
    index = _device_index(device)
    inv_c = 1.0 / hw.peak_flops
    inv_m = 1.0 / hw.hbm_bw
    inv_x = 1.0 / (hw.ici_links * hw.ici_link_bw)
    k_flops, k_bytes, k_coll, k_ops = _HLO_FULL_KEYS
    rt_c, rt_m, rt_x = (TERM_PREFIX + t for t in ROOFLINE_TERMS)
    dom = tuple(DOMINANT_PREFIX + t for t in ROOFLINE_TERMS)
    norm_cache = _NORM_CACHE
    index_get = index.get
    # (metrics, self_metrics, bound, bound - sum(child bounds)) per annotated
    # node, post-order; occupancy is written in one flat scaling loop below.
    pending: list[tuple[dict, dict, float, float]] = []

    def attribute(node: CallNode, is_root: bool) -> tuple[float, float, float, float, float]:
        """Returns the node's attributed (flops, bytes, coll_bytes, ops, bound)."""
        f = b = cb = o = kb = 0.0
        for c in node.children.values():
            cf, cbt, ccb, co, cbd = attribute(c, False)
            f += cf
            b += cbt
            cb += ccb
            o += co
            kb += cbd
        if is_root:
            hit = None
        else:
            name = node.name
            normed = norm_cache.get(name)
            if normed is None:
                normed = norm_cache[name] = _norm(name)
            hit = index_get(normed)
        sf, sb, scb, so = f, b, cb, o
        if hit is not None:
            if hit[0] > f:
                f = hit[0]
            if hit[1] > b:
                b = hit[1]
            if hit[2] > cb:
                cb = hit[2]
            if hit[3] > o:
                o = hit[3]
        if f or b or cb or o:
            m = node._metrics
            sm = node._self_metrics
            if f:
                m[k_flops] = f
                if f > sf:
                    sm[k_flops] = f - sf
            if b:
                m[k_bytes] = b
                if b > sb:
                    sm[k_bytes] = b - sb
            if cb:
                m[k_coll] = cb
                if cb > scb:
                    sm[k_coll] = cb - scb
            if o:
                m[k_ops] = o
                if o > so:
                    sm[k_ops] = o - so
            tc = f * inv_c
            tm = b * inv_m
            tx = cb * inv_x
            bound, which = tc, 0
            if tm > bound:
                bound, which = tm, 1
            if tx > bound:
                bound, which = tx, 2
            if bound > 0:
                m[rt_c] = tc
                m[rt_m] = tm
                m[rt_x] = tx
                m[dom[which]] = bound
                pending.append((m, sm, bound, bound - kb))
                return f, b, cb, o, bound
        return f, b, cb, o, (kb if node.children else 0.0)

    *_vals, t_step = attribute(merged.root, True)
    if t_step > 0:
        inv_t = 1.0 / t_step
        for m, sm, bound, resid in pending:
            m[OCCUPANCY] = bound * inv_t
            if resid > 0:
                sm[OCCUPANCY] = resid * inv_t
    return merged


def dominant_term(metrics: Mapping[str, float]) -> str | None:
    """The node's dominant roofline term, read back from annotation metrics."""
    best, best_v = None, 0.0
    for t in ROOFLINE_TERMS:
        v = metrics.get(DOMINANT_PREFIX + t, 0.0)
        if v > best_v:
            best, best_v = t, v
    return best


def select_plane(
    host: CallTree,
    device: CallTree | None,
    plane: str,
    *,
    hw: HardwareSpec = V5E,
    profile: str | None = None,
    static: CallTree | None = None,
) -> CallTree:
    """Resolve one of the plane views, or raise.

    ``ValueError`` for an unknown plane name (caller bug / HTTP 400);
    :class:`PlaneError` with a remedy hint when the plane's artifact
    (device tree, static tree) is missing (HTTP 404 / CLI exit 4 — never a
    vacuous empty view).
    """
    if plane not in PLANES:
        raise ValueError(f"unknown plane {plane!r} (choose from {', '.join(PLANES)})")
    if plane == "host":
        return host
    if plane == "static":
        if static is None:
            raise PlaneError(missing_static_hint(profile))
        return static
    if device is None:
        raise PlaneError(missing_device_hint(profile))
    if plane == "device":
        return device
    return annotate_tree(host, device, hw)
