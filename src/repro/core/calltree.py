"""Hierarchical call-tree: the paper's central data structure (Fig. 7).

Samples (stacks, root->leaf) sharing a common prefix merge into one path and
their counters accumulate on every shared node; after the first divergence the
paths split, and the *same* callee reached from *different* callers is kept as
a distinct call-site with its own counters.

Counters are generalized to a metrics dict so the same structure serves both
profiling planes:

* host plane  — ``{"samples": 1.0}`` per sampled stack (the paper's counters);
* device plane — ``{"flops": ..., "bytes": ..., "coll_bytes": ...}`` per HLO op,
  keyed by the op's ``op_name`` metadata path (the "call-stack of the simulated
  system").

Views (paper §III-D):

* ``flatten()``     — all nodes with an identical name merged, counters summed;
* ``levels(n)``     — tree truncated at depth ``n``; deeper nodes aggregate into
                      their level-``n`` ancestor (``n=-1`` expands to the leaves);
* ``zoom(root)``    — re-root at every node matching ``root`` (name or predicate),
                      merging the matching subtrees;
* ``filtered(...)`` — whitelist / blacklist by node name.

Trees support ``merge`` (cross-host aggregation) and ``diff`` (windowed deltas
for the anomaly detector).

Hot-counter fast lane
---------------------

The host plane bumps exactly one metric (``samples``) on every node of every
ingested stack, thousands of times per second, while the device plane needs
the open-ended metrics schema.  ``CallNode`` therefore carries a dedicated
``samples``/``self_samples`` float pair beside the generalized dicts: the
cached-path ingestion fast lane (:meth:`CallTree.path_nodes` +
:meth:`CallTree.add_stack_nodes`, used by the profilerd daemon and the thread
backend) bumps only those floats — no hashing, no dict churn.  Reading the
``metrics``/``self_metrics`` properties folds any pending fast-lane counts
into the dicts first, so every existing consumer (views, reports, JSON,
detector) sees one coherent metrics mapping and never needs to know the fast
lane exists.
"""

from __future__ import annotations

import json
from collections.abc import Callable, Iterable, Iterator, Mapping, Sequence

Metrics = dict[str, float]
FramePredicate = Callable[[str], bool]

SAMPLES = "samples"


def _as_predicate(sel: str | FramePredicate) -> FramePredicate:
    if callable(sel):
        return sel
    return lambda name: name == sel


class CallNode:
    """One call-site: a function name reached through a unique caller chain."""

    __slots__ = ("name", "samples", "self_samples", "_metrics", "_self_metrics", "children")

    def __init__(
        self,
        name: str,
        metrics: Metrics | None = None,
        self_metrics: Metrics | None = None,
        children: dict[str, "CallNode"] | None = None,
    ):
        self.name = name
        # Fast-lane pending counts, folded into the dicts on read.
        self.samples = 0.0
        self.self_samples = 0.0
        self._metrics: Metrics = metrics if metrics is not None else {}
        self._self_metrics: Metrics = self_metrics if self_metrics is not None else {}
        self.children: dict[str, "CallNode"] = children if children is not None else {}

    # -- fast-lane / dict coherence -----------------------------------------

    @property
    def metrics(self) -> Metrics:
        """Inclusive metrics: this node and everything below it."""
        if self.samples:
            m = self._metrics
            m[SAMPLES] = m.get(SAMPLES, 0.0) + self.samples
            self.samples = 0.0
        return self._metrics

    @metrics.setter
    def metrics(self, value: Metrics) -> None:
        self.samples = 0.0
        self._metrics = value

    @property
    def self_metrics(self) -> Metrics:
        """Exclusive ("self") metrics: samples whose stack *ended* here."""
        if self.self_samples:
            m = self._self_metrics
            m[SAMPLES] = m.get(SAMPLES, 0.0) + self.self_samples
            self.self_samples = 0.0
        return self._self_metrics

    @self_metrics.setter
    def self_metrics(self, value: Metrics) -> None:
        self.self_samples = 0.0
        self._self_metrics = value

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"CallNode({self.name!r}, {self.metrics!r}, {self.self_metrics!r}, "
            f"children={list(self.children)!r})"
        )

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, CallNode):
            return NotImplemented
        return (
            self.name == other.name
            and self.metrics == other.metrics
            and self.self_metrics == other.self_metrics
            and self.children == other.children
        )

    __hash__ = object.__hash__  # identity hash: nodes are mutable accumulators

    # -- counter plumbing ---------------------------------------------------

    def _bump(self, into: Metrics, delta: Mapping[str, float]) -> None:
        for k, v in delta.items():
            into[k] = into.get(k, 0.0) + v

    def add(self, delta: Mapping[str, float], *, leaf: bool) -> None:
        self._bump(self.metrics, delta)
        if leaf:
            self._bump(self.self_metrics, delta)

    def child(self, name: str) -> "CallNode":
        node = self.children.get(name)
        if node is None:
            node = CallNode(name)
            self.children[name] = node
        return node

    # -- traversal ----------------------------------------------------------

    def walk(self, path: tuple[str, ...] = ()) -> Iterator[tuple[tuple[str, ...], "CallNode"]]:
        here = path + (self.name,)
        yield here, self
        for c in self.children.values():
            yield from c.walk(here)

    def total(self, metric: str = SAMPLES) -> float:
        return self.metrics.get(metric, 0.0)

    def depth(self) -> int:
        if not self.children:
            return 1
        return 1 + max(c.depth() for c in self.children.values())

    def copy(self) -> "CallNode":
        return CallNode(
            self.name,
            dict(self.metrics),
            dict(self.self_metrics),
            {k: v.copy() for k, v in self.children.items()},
        )

    def merge_from(self, other: "CallNode") -> None:
        """Accumulate ``other`` (same name) into this node — Fig. 7 semantics."""
        self._bump(self.metrics, other.metrics)
        self._bump(self.self_metrics, other.self_metrics)
        for name, oc in other.children.items():
            self.child(name).merge_from(oc)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "metrics": self.metrics,
            "self": self.self_metrics,
            "children": [c.to_dict() for c in self.children.values()],
        }

    @staticmethod
    def from_dict(d: dict) -> "CallNode":
        node = CallNode(d["name"], dict(d.get("metrics", {})), dict(d.get("self", {})))
        for cd in d.get("children", []):
            c = CallNode.from_dict(cd)
            node.children[c.name] = c
        return node


class CallTree:
    """A merged collection of stack samples with the paper's view controls."""

    ROOT = "<root>"

    def __init__(self, root: CallNode | None = None):
        self.root = root if root is not None else CallNode(self.ROOT)

    # -- ingestion ------------------------------------------------------------

    def add_stack(self, frames: Sequence[str], metrics: Mapping[str, float] | None = None) -> None:
        """Merge one sample. ``frames`` are ordered root -> leaf."""
        if metrics is None:
            # Host-plane default ({samples: 1}): take the float fast lane.
            node = self.root
            node.samples += 1.0
            for frame in frames:
                node = node.child(frame)
                node.samples += 1.0
            node.self_samples += 1.0
            return
        node = self.root
        node.add(metrics, leaf=not frames)
        for i, frame in enumerate(frames):
            node = node.child(frame)
            node.add(metrics, leaf=(i == len(frames) - 1))

    def path_nodes(self, frames: Sequence[str]) -> list[CallNode]:
        """Materialize (without bumping) the node chain for a root->leaf path.

        Returns ``[root, node(frames[0]), ..., node(frames[-1])]``.  Callers
        cache the chain keyed on the interned stack and replay it through
        :meth:`add_stack_nodes`, turning repeated-sample ingestion into an
        O(depth) float-add loop with zero hashing and zero allocation.
        """
        node = self.root
        chain = [node]
        for frame in frames:
            node = node.child(frame)
            chain.append(node)
        return chain

    @staticmethod
    def add_stack_nodes(chain: Sequence[CallNode], count: float = 1.0) -> None:
        """Bump one sample along a prebuilt chain (the ingestion fast lane)."""
        for node in chain:
            node.samples += count
        chain[-1].self_samples += count

    def merge(self, other: "CallTree") -> "CallTree":
        """Merge another tree into this one (e.g. per-host trees at rendezvous)."""
        self.root.merge_from(other.root)
        return self

    def copy(self) -> "CallTree":
        return CallTree(self.root.copy())

    def diff(self, earlier: "CallTree") -> "CallTree":
        """Windowed delta: metrics now minus metrics at an earlier snapshot.

        Nodes whose metrics are unchanged and that have no changed descendants
        are dropped, so detector windows only see recent activity.
        """

        def sub(now: CallNode, before: CallNode | None) -> CallNode | None:
            bm = before.metrics if before else {}
            bs = before.self_metrics if before else {}
            out = CallNode(now.name)
            for k, v in now.metrics.items():
                d = v - bm.get(k, 0.0)
                if d:
                    out.metrics[k] = d
            for k, v in now.self_metrics.items():
                d = v - bs.get(k, 0.0)
                if d:
                    out.self_metrics[k] = d
            for name, c in now.children.items():
                cb = before.children.get(name) if before else None
                sc = sub(c, cb)
                if sc is not None:
                    out.children[name] = sc
            if not out.metrics and not out.self_metrics and not out.children:
                return None
            return out

        delta = sub(self.root, earlier.root)
        return CallTree(delta if delta is not None else CallNode(self.ROOT))

    # -- views (paper §III-D / Fig. 7) -----------------------------------------

    def flatten(self, metric: str = SAMPLES) -> dict[str, float]:
        """Flattened view: counters for identical function names merged.

        Inclusive counters are *not* simply summable across a path (a frame may
        appear once per call chain), so the flattened view sums each name's
        inclusive metric over all call-sites where it appears, matching the
        paper's flattened view of Fig. 7 (a=a1+a2, b=b1+b2, e=e1+e2 ...).
        """
        out: dict[str, float] = {}
        for _path, node in self.root.walk():
            if node is self.root:
                continue
            out[node.name] = out.get(node.name, 0.0) + node.metrics.get(metric, 0.0)
        return out

    def levels(self, n: int) -> "CallTree":
        """N-level view: keep ``n`` levels below the root; deeper nodes fold
        into their last kept ancestor (their metrics are already inclusive, so
        folding == dropping children). ``n = -1`` returns a full copy.
        """
        if n < 0:
            return self.copy()

        def trunc(node: CallNode, level: int) -> CallNode:
            out = CallNode(node.name, dict(node.metrics), dict(node.self_metrics))
            if level < n:
                for name, c in node.children.items():
                    out.children[name] = trunc(c, level + 1)
            else:
                # Fold all descendants into this node's self metrics.
                out.self_metrics = dict(out.metrics)
            return out

        return CallTree(trunc(self.root, 0))

    def zoom(self, selector: str | FramePredicate) -> "CallTree":
        """Re-root at every node matching ``selector``; matching subtrees merge.

        This implements the paper's root-of-interest control (e.g. "all
        functions related to the IEW stage"), here e.g. zoom("attention").
        """
        pred = _as_predicate(selector)
        out = CallTree()
        found: list[CallNode] = []

        def visit(node: CallNode) -> None:
            if node is not self.root and pred(node.name):
                found.append(node)
                return  # do not descend: the whole subtree belongs to the match
            for c in node.children.values():
                visit(c)

        visit(self.root)
        for node in found:
            out.root.merge_from(CallNode(out.ROOT, dict(node.metrics), dict(node.self_metrics), {node.name: node.copy()}))
        return out

    def filtered(
        self,
        whitelist: Iterable[str] | None = None,
        blacklist: Iterable[str] | None = None,
        substring: bool = True,
    ) -> "CallTree":
        """White/blacklist view. A blacklisted node is removed with its subtree
        (excluded from breakdown totals, like the artifact's parser cfg); with a
        whitelist, only paths touching a whitelisted name survive.
        """
        wl = list(whitelist) if whitelist else None
        bl = list(blacklist) if blacklist else []

        def match(name: str, pats: Iterable[str]) -> bool:
            return any((p in name) if substring else (p == name) for p in pats)

        def keep(node: CallNode) -> CallNode | None:
            if match(node.name, bl):
                return None
            kept_children = {}
            for name, c in node.children.items():
                kc = keep(c)
                if kc is not None:
                    kept_children[name] = kc
            if wl is not None and not match(node.name, wl) and not kept_children:
                return None
            out = CallNode(node.name, dict(node.metrics), dict(node.self_metrics))
            out.children = kept_children
            return out

        kept = {}
        for name, c in self.root.children.items():
            kc = keep(c)
            if kc is not None:
                kept[name] = kc
        root = CallNode(self.ROOT, dict(self.root.metrics), dict(self.root.self_metrics))
        root.children = kept
        return CallTree(root)

    # -- analysis helpers -------------------------------------------------------

    def total(self, metric: str = SAMPLES) -> float:
        return self.root.total(metric)

    def shares(self, metric: str = SAMPLES, *, self_only: bool = False) -> dict[tuple[str, ...], float]:
        """Per-call-site share of the root total (detector input)."""
        total = self.total(metric)
        if total <= 0:
            return {}
        out = {}
        for path, node in self.root.walk():
            if node is self.root:
                continue
            src = node.self_metrics if self_only else node.metrics
            v = src.get(metric, 0.0)
            if v:
                out[path[1:]] = v / total
        return out

    def hot_paths(self, metric: str = SAMPLES, k: int = 10, self_only: bool = True) -> list[tuple[tuple[str, ...], float]]:
        sh = self.shares(metric, self_only=self_only)
        return sorted(sh.items(), key=lambda kv: -kv[1])[:k]

    def depth(self) -> int:
        return self.root.depth() - 1

    def node_count(self) -> int:
        """Distinct call-sites in the tree (excluding the synthetic root)."""
        return sum(1 for _ in self.root.walk()) - 1

    # -- serialization ------------------------------------------------------------

    def to_json(self, **kw) -> str:
        return json.dumps(self.root.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "CallTree":
        return CallTree(CallNode.from_dict(json.loads(s)))

    def render(self, metric: str = SAMPLES, max_depth: int = -1, min_share: float = 0.0) -> str:
        """ASCII rendering used in reports/benchmark CSVs."""
        total = max(self.total(metric), 1e-12)
        lines: list[str] = []

        def rec(node: CallNode, indent: int) -> None:
            if max_depth >= 0 and indent > max_depth:
                return
            share = node.metrics.get(metric, 0.0) / total
            if node is not self.root and share < min_share:
                return
            if node is not self.root:
                lines.append(f"{'  ' * indent}{node.name}  {metric}={node.metrics.get(metric, 0.0):.6g}  ({share:6.2%})")
            for c in sorted(node.children.values(), key=lambda c: -c.metrics.get(metric, 0.0)):
                rec(c, indent + (0 if node is self.root else 1))

        rec(self.root, 0)
        return "\n".join(lines)
