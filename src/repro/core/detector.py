"""Dominance-threshold anomaly detection (paper §V-D, Fig. 13).

The paper's key insight: when a coherence protocol dead/livelocks, gem5 keeps
executing the *same* protocol actions, so the runtime breakdown degenerates —
one action's share exceeds a threshold (90 %) — and the profiler can flag it,
**checkpoint the simulation**, and warn, with no a-priori instrumentation.

The distributed-training analogues detected here with the same mechanism:

* **hang / collective deadlock** — a stuck all-reduce (dead peer) pins the
  host in one dispatch/wait frame for entire windows;
* **livelock / spin** — a retry loop (data pipeline refill, lock spin)
  dominates the window tree exactly like the paper's recycled mandatory-queue
  load (its ``load_hit`` signature);
* **straggler** — one host's window tree diverges from the fleet's merged
  tree (share-distance metric), the multi-pod extension of the mechanism;
* **input starvation** — the ``data::`` subtree share exceeds its budget.

Detection operates on *windowed deltas* (``CallTree.diff``) so long-running
jobs cannot dilute a fresh anomaly, and fires ordered callbacks: warn →
checkpoint → (optionally) abort/restart, mirroring the paper's
warn+checkpoint flow while integrating with the launcher's restart policy.
"""

from __future__ import annotations

import time
import traceback
from collections import deque
from dataclasses import dataclass, field
from collections.abc import Callable, Mapping, Sequence

from .calltree import SAMPLES, CallTree


@dataclass
class Rule:
    """One dominance rule: if a node matching ``pattern`` holds more than
    ``threshold`` of the window's samples for ``consecutive`` windows, fire."""

    pattern: str = ""  # substring of the call-site path ("" matches any node)
    threshold: float = 0.90  # the paper's default
    consecutive: int = 1
    metric: str = SAMPLES
    self_only: bool = True
    kind: str = "LIVELOCK_SUSPECT"
    min_window_total: float = 4.0  # don't fire on nearly-empty windows


@dataclass
class AnomalyEvent:
    kind: str
    path: tuple[str, ...]
    share: float
    rule: Rule
    window_index: int
    wall_time: float = field(default_factory=time.time)

    def describe(self) -> str:
        return (
            f"[{self.kind}] {'/'.join(self.path)} holds {self.share:.1%} of window "
            f"{self.window_index} (threshold {self.rule.threshold:.0%})"
        )


class DominanceDetector:
    """Sliding-window dominance detector over sampled call-trees."""

    def __init__(
        self,
        rules: Sequence[Rule] | None = None,
        on_anomaly: Sequence[Callable[[AnomalyEvent], None]] | None = None,
    ):
        self.rules = list(rules) if rules else [Rule()]
        self.callbacks: list[Callable[[AnomalyEvent], None]] = list(on_anomaly or [])
        self.events: list[AnomalyEvent] = []
        # A verdict callback (warn/checkpoint/abort action) that raises must
        # not take down the observer loop feeding it — the detector is exactly
        # the component that has to survive a sick process.  Failures land
        # here and, when set, in ``on_callback_error(event, traceback_str)``.
        self.callback_failures: deque = deque(maxlen=32)
        self.on_callback_error: Callable[[AnomalyEvent, str], None] | None = None
        self._prev: CallTree | None = None
        self._streaks: dict[int, int] = {}
        self._window = 0

    def add_callback(self, fn: Callable[[AnomalyEvent], None]) -> None:
        self.callbacks.append(fn)

    def observe(self, snapshot: CallTree) -> list[AnomalyEvent]:
        """Feed one snapshot (cumulative tree); detector diffs internally."""
        window = snapshot.diff(self._prev) if self._prev is not None else snapshot.copy()
        self._prev = snapshot
        self._window += 1
        fired: list[AnomalyEvent] = []
        for i, rule in enumerate(self.rules):
            total = window.total(rule.metric)
            if total < rule.min_window_total:
                self._streaks[i] = 0
                continue
            shares = window.shares(rule.metric, self_only=rule.self_only)
            hit: tuple[tuple[str, ...], float] | None = None
            for path, share in shares.items():
                if share >= rule.threshold and (not rule.pattern or any(rule.pattern in p for p in path)):
                    if hit is None or share > hit[1]:
                        hit = (path, share)
            if hit is None:
                self._streaks[i] = 0
                continue
            self._streaks[i] = self._streaks.get(i, 0) + 1
            if self._streaks[i] >= rule.consecutive:
                ev = AnomalyEvent(rule.kind, hit[0], hit[1], rule, self._window)
                fired.append(ev)
                self.events.append(ev)
                for cb in self.callbacks:
                    try:
                        cb(ev)
                    except Exception:
                        tb = traceback.format_exc()
                        self.callback_failures.append((ev, tb))
                        if self.on_callback_error is not None:
                            try:
                                self.on_callback_error(ev, tb)
                            except Exception:
                                pass  # the error sink must never recurse
        return fired


LIVELOCK = "LIVELOCK"
DOMINANT = "DOMINANT"
SHARE_DRIFT = "SHARE_DRIFT"
LIVELOCK_CLEARED = "LIVELOCK_CLEARED"


def share_distance(a: Mapping[str, float], b: Mapping[str, float]) -> float:
    """Total-variation distance between two (already normalized) share vectors."""
    keys = set(a) | set(b)
    return 0.5 * sum(abs(a.get(k, 0.0) - b.get(k, 0.0)) for k in keys)


def flat_shares(tree: CallTree, metric: str = SAMPLES) -> dict[str, float]:
    """Flattened per-name share vector (sums over call-sites, normalized)."""
    from .report import name_shares  # lazy: report imports from this module too

    return name_shares(tree, metric, self_only=False)


def segment_phases(vectors: Sequence[Mapping[str, float]], boundary: float = 0.25) -> list[tuple[int, int]]:
    """Segment an epoch sequence into phases (paper: "pinpoint when it occurs").

    Consecutive epochs whose share vectors stay within ``boundary`` TV
    distance belong to one phase; a jump starts a new one.  Returns inclusive
    ``(start_epoch_index, end_epoch_index)`` pairs over the input sequence.
    """
    if not vectors:
        return []
    phases: list[tuple[int, int]] = []
    start = 0
    for i in range(1, len(vectors)):
        if share_distance(vectors[i], vectors[i - 1]) > boundary:
            phases.append((start, i - 1))
            start = i
    phases.append((start, len(vectors) - 1))
    return phases


@dataclass
class TrendRule:
    """Epoch-trend thresholds for :class:`TrendDetector`.

    The paper's dominance threshold alone cannot tell a livelock from a
    legitimately hot steady-state loop; the disambiguator is *progress*: a
    livelocked target repeats the identical actions, so its progress counter
    (by default the number of distinct call-sites ever sealed — a spinning
    target mints no new stacks) stops growing while the dominance holds.
    """

    threshold: float = 0.90  # dominance share (the paper's default)
    epochs: int = 3  # sustained dominant+stalled epochs before LIVELOCK
    progress_epsilon: float = 0.0  # growth <= eps counts as "no progress"
    drift_threshold: float = 0.35  # TV distance vs the trailing baseline
    baseline_window: int = 8  # trailing epochs forming the drift baseline
    min_baseline_epochs: int = 3
    metric: str = SAMPLES
    self_only: bool = True
    min_epoch_total: float = 4.0  # ignore nearly-empty epochs


@dataclass
class TrendVerdict:
    """One epoch-trend finding, stamped with the epoch where it began."""

    kind: str  # LIVELOCK | DOMINANT | SHARE_DRIFT | LIVELOCK_CLEARED
    path: tuple[str, ...]
    share: float  # dominant share, or TV distance for SHARE_DRIFT
    epoch: int
    began_epoch: int
    wall_time: float = field(default_factory=time.time)

    @property
    def latency_epochs(self) -> int:
        """Epochs between the condition's onset and this verdict firing —
        the detection latency the fault scoreboard grades."""
        return max(0, self.epoch - self.began_epoch)

    def describe(self) -> str:
        what = "/".join(self.path) if self.path else "<distribution>"
        return (
            f"[{self.kind}] {what} share={self.share:.1%} at epoch {self.epoch} "
            f"(began epoch {self.began_epoch})"
        )


class TrendDetector:
    """Trend analysis over sealed epoch windows (timeline-aware detection).

    Consumes one *window* tree (the epoch's activity delta, not the
    cumulative tree) plus a progress counter per epoch and reports:

    * ``DOMINANT``   — one call-site holds >= ``threshold`` of the window
      while progress still grows (a hot loop, not an anomaly by itself);
    * ``LIVELOCK``   — the same dominance **with zero progress growth** for
      ``epochs`` consecutive epochs, stamped with the epoch where the
      stalled-dominance run began;
    * ``SHARE_DRIFT``— the window's share distribution moved more than
      ``drift_threshold`` (TV distance) away from the trailing
      ``baseline_window``-epoch mean, stamped with the first drifting epoch.
    * ``LIVELOCK_CLEARED`` — a previously-reported LIVELOCK whose dominance
      broke or whose progress resumed; without this transition a cleared
      fault reads as permanently wedged, so recovery is first-class.

    Each distinct ``(kind, path, began_epoch)`` is reported once.
    """

    def __init__(self, rule: TrendRule | None = None):
        self.rule = rule if rule is not None else TrendRule()
        self.events: list[TrendVerdict] = []
        self._epoch = -1
        self._last_progress: float | None = None
        self._dom_path: tuple[str, ...] | None = None
        self._dom_began = 0
        self._stall_began: int | None = None
        self._drift_began: int | None = None
        self._livelock_active: tuple[tuple[str, ...], int] | None = None
        self._baseline: deque = deque(maxlen=max(1, self.rule.baseline_window))
        self._emitted: set[tuple[str, tuple[str, ...], int]] = set()

    # -- scoreboard accessors ------------------------------------------------

    @property
    def livelock_active(self) -> bool:
        return self._livelock_active is not None

    def detections(self, kind: str | None = None) -> list[TrendVerdict]:
        if kind is None:
            return list(self.events)
        return [v for v in self.events if v.kind == kind]

    def first_detection(self, kind: str) -> TrendVerdict | None:
        for v in self.events:
            if v.kind == kind:
                return v
        return None

    def detection_latency(self, kind: str) -> int | None:
        """Epochs from onset to first verdict of ``kind`` (None if never)."""
        v = self.first_detection(kind)
        return None if v is None else v.latency_epochs

    def _emit(self, out: list[TrendVerdict], kind: str, path: tuple[str, ...], share: float, began: int, wall_time: float) -> None:
        key = (kind, path, began)
        if key in self._emitted:
            return
        self._emitted.add(key)
        v = TrendVerdict(kind, path, share, self._epoch, began, wall_time)
        self.events.append(v)
        out.append(v)

    def observe_epoch(
        self,
        window: CallTree,
        progress: float = 0.0,
        epoch: int | None = None,
        wall_time: float | None = None,
    ) -> list[TrendVerdict]:
        rule = self.rule
        self._epoch = epoch if epoch is not None else self._epoch + 1
        wall = wall_time if wall_time is not None else time.time()
        out: list[TrendVerdict] = []

        # Progress stall tracking runs every epoch so a stall that predates
        # the dominance onset is stamped where it actually began.
        if self._last_progress is None or progress - self._last_progress > rule.progress_epsilon:
            self._stall_began = None
        elif self._stall_began is None:
            self._stall_began = self._epoch
        self._last_progress = progress

        total = window.total(rule.metric)
        if total < rule.min_epoch_total:
            self._dom_path = None
            return out

        # -- dominance / livelock -------------------------------------------
        shares = window.shares(rule.metric, self_only=rule.self_only)
        top: tuple[tuple[str, ...], float] | None = None
        for path, share in shares.items():
            if share >= rule.threshold and (top is None or share > top[1]):
                top = (path, share)
        # Recovery first: an active LIVELOCK clears the moment its dominance
        # breaks or progress resumes — stamped with the original onset epoch
        # so time-wedged = cleared.epoch - cleared.began_epoch.
        if self._livelock_active is not None:
            lpath, lbegan = self._livelock_active
            if self._stall_began is None or top is None or top[0] != lpath:
                self._emit(out, LIVELOCK_CLEARED, lpath, shares.get(lpath, 0.0), lbegan, wall)
                self._livelock_active = None
        if top is None:
            self._dom_path = None
        else:
            path, share = top
            if self._dom_path != path:
                self._dom_path = path
                self._dom_began = self._epoch
            if self._stall_began is not None:
                began = max(self._dom_began, self._stall_began)
                if self._epoch - began + 1 >= rule.epochs:
                    self._emit(out, LIVELOCK, path, share, began, wall)
                    self._livelock_active = (path, began)
                else:
                    self._emit(out, DOMINANT, path, share, self._dom_began, wall)
            else:
                self._emit(out, DOMINANT, path, share, self._dom_began, wall)

        # -- distribution drift vs trailing baseline ------------------------
        cur = flat_shares(window, rule.metric)
        if len(self._baseline) >= rule.min_baseline_epochs:
            base: dict[str, float] = {}
            for vec in self._baseline:
                for k, v in vec.items():
                    base[k] = base.get(k, 0.0) + v
            n = len(self._baseline)
            base = {k: v / n for k, v in base.items()}
            tv = share_distance(cur, base)
            if tv >= rule.drift_threshold:
                if self._drift_began is None:
                    self._drift_began = self._epoch
                self._emit(out, SHARE_DRIFT, (), tv, self._drift_began, wall)
            else:
                self._drift_began = None
        self._baseline.append(cur)
        return out


class StragglerDetector:
    """Multi-host extension: flag hosts whose window tree diverges from the
    fleet. Distance = total-variation distance between *self*-share vectors
    (flattened by frame name); a straggler burns its samples in a different
    place (e.g. a collective-wait frame) than its peers.

    Self shares, not inclusive: real stacks share a deep common prefix
    (interpreter bootstrap, the train loop), and inclusive counters would let
    that shared mass dilute any leaf-level divergence below threshold."""

    def __init__(self, threshold: float = 0.5, metric: str = SAMPLES):
        self.threshold = threshold
        self.metric = metric

    def _shares(self, tree: CallTree) -> dict[str, float]:
        flat: dict[str, float] = {}
        for _path, node in tree.root.walk():
            if node is tree.root:
                continue
            v = node.self_metrics.get(self.metric, 0.0)
            if v:
                flat[node.name] = flat.get(node.name, 0.0) + v
        total = sum(flat.values()) or 1.0
        return {k: v / total for k, v in flat.items()}

    def observe(self, host_trees: dict[str, CallTree]) -> list[tuple[str, float]]:
        if len(host_trees) < 2:
            return []
        merged = CallTree()
        for t in host_trees.values():
            merged.merge(t.copy())
        ref = self._shares(merged)
        out = []
        for host, tree in host_trees.items():
            mine = self._shares(tree)
            keys = set(ref) | set(mine)
            tv = 0.5 * sum(abs(mine.get(k, 0.0) - ref.get(k, 0.0)) for k in keys)
            if tv >= self.threshold:
                out.append((host, tv))
        return sorted(out, key=lambda kv: -kv[1])


class WatchdogLoop:
    """Glue: sampler -> detector at a fixed cadence, on its own thread.

    ``actions`` receive every event; a typical production wiring is
    ``[checkpoint_manager.save_emergency, launcher.report]`` — i.e. the
    paper's warn+checkpoint flow.
    """

    def __init__(self, sampler, detector: DominanceDetector, interval_s: float = 2.0):
        self.sampler = sampler
        self.detector = detector
        self.interval_s = interval_s
        # Observe-pass failures (sampler or detector internals) are recorded,
        # never fatal: the watchdog's one job is to keep observing a process
        # that is already misbehaving.  Callback failures are handled one
        # level down by :class:`DominanceDetector` itself.
        self.errors: deque = deque(maxlen=32)
        import threading

        self._stop = threading.Event()
        self._thread: object | None = None
        self._threading = threading

    def start(self) -> "WatchdogLoop":
        t = self._threading.Thread(target=self._run, name="repro-prof-watchdog", daemon=True)
        self._thread = t
        t.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.detector.observe(self.sampler.snapshot())
            except Exception:
                self.errors.append(traceback.format_exc())

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
