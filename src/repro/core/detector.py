"""Dominance-threshold anomaly detection (paper §V-D, Fig. 13).

The paper's key insight: when a coherence protocol dead/livelocks, gem5 keeps
executing the *same* protocol actions, so the runtime breakdown degenerates —
one action's share exceeds a threshold (90 %) — and the profiler can flag it,
**checkpoint the simulation**, and warn, with no a-priori instrumentation.

The distributed-training analogues detected here with the same mechanism:

* **hang / collective deadlock** — a stuck all-reduce (dead peer) pins the
  host in one dispatch/wait frame for entire windows;
* **livelock / spin** — a retry loop (data pipeline refill, lock spin)
  dominates the window tree exactly like the paper's recycled mandatory-queue
  load (its ``load_hit`` signature);
* **straggler** — one host's window tree diverges from the fleet's merged
  tree (share-distance metric), the multi-pod extension of the mechanism;
* **input starvation** — the ``data::`` subtree share exceeds its budget.

Detection operates on *windowed deltas* (``CallTree.diff``) so long-running
jobs cannot dilute a fresh anomaly, and fires ordered callbacks: warn →
checkpoint → (optionally) abort/restart, mirroring the paper's
warn+checkpoint flow while integrating with the launcher's restart policy.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from .calltree import SAMPLES, CallTree


@dataclass
class Rule:
    """One dominance rule: if a node matching ``pattern`` holds more than
    ``threshold`` of the window's samples for ``consecutive`` windows, fire."""

    pattern: str = ""  # substring of the call-site path ("" matches any node)
    threshold: float = 0.90  # the paper's default
    consecutive: int = 1
    metric: str = SAMPLES
    self_only: bool = True
    kind: str = "LIVELOCK_SUSPECT"
    min_window_total: float = 4.0  # don't fire on nearly-empty windows


@dataclass
class AnomalyEvent:
    kind: str
    path: tuple[str, ...]
    share: float
    rule: Rule
    window_index: int
    wall_time: float = field(default_factory=time.time)

    def describe(self) -> str:
        return (
            f"[{self.kind}] {'/'.join(self.path)} holds {self.share:.1%} of window "
            f"{self.window_index} (threshold {self.rule.threshold:.0%})"
        )


class DominanceDetector:
    """Sliding-window dominance detector over sampled call-trees."""

    def __init__(
        self,
        rules: Optional[Sequence[Rule]] = None,
        on_anomaly: Optional[Sequence[Callable[[AnomalyEvent], None]]] = None,
    ):
        self.rules = list(rules) if rules else [Rule()]
        self.callbacks: list[Callable[[AnomalyEvent], None]] = list(on_anomaly or [])
        self.events: list[AnomalyEvent] = []
        self._prev: Optional[CallTree] = None
        self._streaks: dict[int, int] = {}
        self._window = 0

    def add_callback(self, fn: Callable[[AnomalyEvent], None]) -> None:
        self.callbacks.append(fn)

    def observe(self, snapshot: CallTree) -> list[AnomalyEvent]:
        """Feed one snapshot (cumulative tree); detector diffs internally."""
        window = snapshot.diff(self._prev) if self._prev is not None else snapshot.copy()
        self._prev = snapshot
        self._window += 1
        fired: list[AnomalyEvent] = []
        for i, rule in enumerate(self.rules):
            total = window.total(rule.metric)
            if total < rule.min_window_total:
                self._streaks[i] = 0
                continue
            shares = window.shares(rule.metric, self_only=rule.self_only)
            hit: Optional[tuple[tuple[str, ...], float]] = None
            for path, share in shares.items():
                if share >= rule.threshold and (not rule.pattern or any(rule.pattern in p for p in path)):
                    if hit is None or share > hit[1]:
                        hit = (path, share)
            if hit is None:
                self._streaks[i] = 0
                continue
            self._streaks[i] = self._streaks.get(i, 0) + 1
            if self._streaks[i] >= rule.consecutive:
                ev = AnomalyEvent(rule.kind, hit[0], hit[1], rule, self._window)
                fired.append(ev)
                self.events.append(ev)
                for cb in self.callbacks:
                    cb(ev)
        return fired


class StragglerDetector:
    """Multi-host extension: flag hosts whose window tree diverges from the
    fleet. Distance = total-variation distance between flattened share
    vectors; a straggler burns its samples in a different place (e.g. a
    collective-wait frame) than its peers."""

    def __init__(self, threshold: float = 0.5, metric: str = SAMPLES):
        self.threshold = threshold
        self.metric = metric

    def _shares(self, tree: CallTree) -> dict[str, float]:
        flat = tree.flatten(self.metric)
        total = sum(v for v in flat.values()) or 1.0
        return {k: v / total for k, v in flat.items()}

    def observe(self, host_trees: dict[str, CallTree]) -> list[tuple[str, float]]:
        if len(host_trees) < 2:
            return []
        merged = CallTree()
        for t in host_trees.values():
            merged.merge(t.copy())
        ref = self._shares(merged)
        out = []
        for host, tree in host_trees.items():
            mine = self._shares(tree)
            keys = set(ref) | set(mine)
            tv = 0.5 * sum(abs(mine.get(k, 0.0) - ref.get(k, 0.0)) for k in keys)
            if tv >= self.threshold:
                out.append((host, tv))
        return sorted(out, key=lambda kv: -kv[1])


class WatchdogLoop:
    """Glue: sampler -> detector at a fixed cadence, on its own thread.

    ``actions`` receive every event; a typical production wiring is
    ``[checkpoint_manager.save_emergency, launcher.report]`` — i.e. the
    paper's warn+checkpoint flow.
    """

    def __init__(self, sampler, detector: DominanceDetector, interval_s: float = 2.0):
        self.sampler = sampler
        self.detector = detector
        self.interval_s = interval_s
        import threading

        self._stop = threading.Event()
        self._thread: Optional[object] = None
        self._threading = threading

    def start(self) -> "WatchdogLoop":
        t = self._threading.Thread(target=self._run, name="repro-watchdog", daemon=True)
        self._thread = t
        t.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.detector.observe(self.sampler.snapshot())
            except Exception:
                pass

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
