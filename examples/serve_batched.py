"""Batched serving example: continuous-batching decode with the profiler on.

  PYTHONPATH=src python examples/serve_batched.py --requests 12 --batch 4
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.core import SamplerConfig, StackSampler, breakdown
from repro.launch.serve import BatchedServer, Request
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = Model(cfg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, int(rng.integers(3, 9))).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    sampler = StackSampler(SamplerConfig(period_s=0.05)).start()
    server = BatchedServer(model, batch=args.batch, max_len=128)
    stats = server.run(reqs)
    tree = sampler.stop()
    print(json.dumps(stats, indent=1))
    print("host-plane breakdown of the serving loop:")
    for name, share in breakdown(tree, level=3, min_share=0.05):
        print(f"  {share:6.1%}  {name.split('/')[-1]}")
    assert stats["requests_done"] == args.requests


if __name__ == "__main__":
    main()
