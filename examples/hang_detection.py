"""Fig. 13 end-to-end, both profiler backends.

Part 1 (thread backend): a training job develops a livelock mid-run; the
in-process watchdog detects the dominance signature, takes an emergency
checkpoint, and the job restarts from it.

Part 2 (daemon backend): the scenario an in-process helper thread *cannot*
handle — the target's interpreter is fully wedged (here: SIGSTOP, the
stand-in for a GIL held forever in native code), so no helper thread inside
the process can run either.  The target only publishes raw frames to a spool;
the out-of-process ``repro.profilerd`` daemon notices the spool has gone
silent while the pid is still alive and fires a ``TARGET_STALLED`` verdict —
the paper's external-observer architecture earning its keep.

  PYTHONPATH=src python examples/hang_detection.py
"""

import json
import os
import signal
import subprocess
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import Trainer, TrainJobConfig


def injected_livelock_spin(stop):
    x = 0
    while not stop.is_set():
        x += 1


def part1_thread_backend(out_dir="/tmp/repro_hang_demo"):
    """In-process watchdog: livelock -> emergency checkpoint -> restart."""
    import shutil

    shutil.rmtree(out_dir, ignore_errors=True)
    from repro.core import Rule

    job = TrainJobConfig(
        arch="gemma-2b",
        smoke=True,
        steps=12,
        global_batch=4,
        seq_len=48,
        out_dir=out_dir,
        ckpt_every=50,  # only the watchdog will checkpoint
        sample_period_s=0.02,
        watchdog_threshold=0.35,  # the spin shares the single CPU with real work
        # The generic dominance rule is timing-sensitive on a single CPU (jit
        # compilation legitimately dominates early windows); scope a rule to
        # the known injection signature so the demo is deterministic.
        extra_rules=[Rule(pattern="injected_livelock_spin", threshold=0.2,
                          consecutive=2, min_window_total=4, self_only=False)],
    )
    trainer = Trainer(job)

    stop = threading.Event()
    spin = threading.Thread(target=injected_livelock_spin, args=(stop,), daemon=True)

    def inject_later():
        time.sleep(2.0)
        print(">>> injecting livelock (spinning thread) <<<")
        spin.start()

    threading.Thread(target=inject_later, daemon=True).start()
    summary = trainer.run()
    stop.set()

    print(f"anomalies: {summary['anomalies']}")
    steps = trainer.ckpt.list_steps()
    print(f"checkpoints on disk: {steps}")
    assert summary["anomalies"], "watchdog failed to flag the injected livelock"
    _, _, manifest = trainer.ckpt.restore_latest()
    print(f"latest checkpoint tag: {manifest['tag']}, anomaly: {manifest['extra'].get('anomaly')}")

    # restart from the emergency checkpoint
    resumed = Trainer(TrainJobConfig(
        arch="gemma-2b", smoke=True, steps=summary["steps"] + 3, global_batch=4,
        seq_len=48, out_dir=out_dir, ckpt_every=50,
    ))
    summary2 = resumed.run()
    print(f"resumed and ran to step {summary2['steps']}")


_WEDGED_TARGET = r"""
import sys, time
sys.path.insert(0, sys.argv[2])
from repro.core import SamplerConfig, make_sampler

# Daemon backend, externally-drained spool: the only profiling work in this
# process is the raw-frame publisher.
sampler = make_sampler(SamplerConfig(
    backend="daemon", spool_path=sys.argv[1], spawn_daemon=False, period_s=0.05))
sampler.start()
t0 = time.monotonic()
x = 0
while time.monotonic() - t0 < 30.0:   # parent SIGSTOPs us long before this
    x += 1
sampler.stop()
"""


def part2_daemon_backend(out_dir="/tmp/repro_hang_demo_daemon"):
    """Out-of-process daemon: fully wedged target -> TARGET_STALLED."""
    import shutil

    from repro.core.detector import Rule
    from repro.profilerd import DaemonConfig, ProfilerDaemon

    shutil.rmtree(out_dir, ignore_errors=True)
    os.makedirs(out_dir)
    spool = os.path.join(out_dir, "target.spool")
    src = os.path.join(os.path.dirname(__file__), "..", "src")

    target = subprocess.Popen([sys.executable, "-c", _WEDGED_TARGET, spool, src])
    print(f">>> target pid={target.pid} publishing raw frames to {spool} <<<")

    daemon = ProfilerDaemon(DaemonConfig(
        spool_path=spool, publish_interval_s=0.25, stall_timeout_s=1.0,
        rules=[Rule(threshold=0.9, consecutive=2)], max_seconds=30.0,
    ))
    daemon.attach()

    stalled = {"seen": False}

    def watch(d):
        for ev in d.events:
            if ev["kind"] == "TARGET_STALLED" and not stalled["seen"]:
                stalled["seen"] = True
                print(f">>> daemon verdict: {json.dumps(ev)} <<<")
        if stalled["seen"]:
            d.request_stop()  # verdict delivered: end the attach loop

    def wedge_later():
        time.sleep(2.0)
        print(">>> wedging the target's interpreter (SIGSTOP) <<<")
        os.kill(target.pid, signal.SIGSTOP)

    threading.Thread(target=wedge_later, daemon=True).start()
    tree = daemon.run(on_publish=watch)

    os.kill(target.pid, signal.SIGCONT)
    target.terminate()
    target.wait()

    print(f"daemon merged {daemon.n_stacks} stacks before the wedge; hot paths:")
    for path, share in tree.hot_paths(k=3):
        print(f"  {share:7.2%}  {'/'.join(path)}")
    assert stalled["seen"], "daemon failed to flag the wedged target"
    assert daemon.n_stacks > 0, "daemon streamed no samples before the wedge"
    print(f"artifacts: {sorted(os.listdir(daemon.out_dir))}")


def main():
    part1_thread_backend()
    print()
    part2_daemon_backend()


if __name__ == "__main__":
    main()
