"""Fig. 13 end-to-end: a training job develops a livelock mid-run; the
watchdog detects the dominance signature, takes an emergency checkpoint, and
the job restarts from it.

A worker thread starts spinning (a stuck collective / lock-retry analogue)
partway through training. The dominance detector flags it within a couple of
windows, the checkpoint manager writes an 'emergency'-tagged checkpoint with
the anomaly recorded in the manifest, and a fresh Trainer resumes from it.

  PYTHONPATH=src python examples/hang_detection.py
"""

import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import Trainer, TrainJobConfig


def injected_livelock_spin(stop):
    x = 0
    while not stop.is_set():
        x += 1


def main(out_dir="/tmp/repro_hang_demo"):
    import shutil

    shutil.rmtree(out_dir, ignore_errors=True)
    job = TrainJobConfig(
        arch="gemma-2b",
        smoke=True,
        steps=12,
        global_batch=4,
        seq_len=48,
        out_dir=out_dir,
        ckpt_every=50,  # only the watchdog will checkpoint
        sample_period_s=0.02,
        watchdog_threshold=0.35,  # the spin shares the single CPU with real work
    )
    trainer = Trainer(job)

    stop = threading.Event()
    spin = threading.Thread(target=injected_livelock_spin, args=(stop,), daemon=True)

    def inject_later():
        time.sleep(2.0)
        print(">>> injecting livelock (spinning thread) <<<")
        spin.start()

    threading.Thread(target=inject_later, daemon=True).start()
    summary = trainer.run()
    stop.set()

    print(f"anomalies: {summary['anomalies']}")
    steps = trainer.ckpt.list_steps()
    print(f"checkpoints on disk: {steps}")
    assert summary["anomalies"], "watchdog failed to flag the injected livelock"
    _, _, manifest = trainer.ckpt.restore_latest()
    print(f"latest checkpoint tag: {manifest['tag']}, anomaly: {manifest['extra'].get('anomaly')}")

    # restart from the emergency checkpoint
    resumed = Trainer(TrainJobConfig(
        arch="gemma-2b", smoke=True, steps=summary["steps"] + 3, global_batch=4,
        seq_len=48, out_dir=out_dir, ckpt_every=50,
    ))
    summary2 = resumed.run()
    print(f"resumed and ran to step {summary2['steps']}")


if __name__ == "__main__":
    main()
