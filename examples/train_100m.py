"""End-to-end training driver: the full xlstm-125m (~125M params) on the real
Trainer (fault tolerance, checkpoints, watchdog, resumable data).

On a TPU slice this is the production entry point; on this CPU container a
~125M model trains slowly, so the default invocation runs a short smoke
segment — pass --steps 300 --full for the real thing.

  PYTHONPATH=src python examples/train_100m.py                 # CPU demo
  PYTHONPATH=src python examples/train_100m.py --steps 300 --full --batch 32 --seq 1024
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import Trainer, TrainJobConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--full", action="store_true", help="full 125M config (default: reduced)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--out", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    job = TrainJobConfig(
        arch="xlstm-125m",
        smoke=not args.full,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        lr=1e-3,
        out_dir=args.out,
        ckpt_every=max(args.steps // 3, 1),
    )
    summary = Trainer(job).run()
    print(json.dumps(summary, indent=1))
    assert summary["final_loss"] < summary["first_loss"], "loss did not improve"


if __name__ == "__main__":
    main()
