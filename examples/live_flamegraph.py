"""Live flamegraph walkthrough: profile a running workload, query it over
HTTP *while it runs*, and save a self-contained flamegraph.

The whole read side of the profiling plane in one script, no jax required:

1. park a worker in a busy loop and publish raw frames through the
   out-of-process agent (the target never resolves a symbol);
2. attach a :class:`~repro.profilerd.daemon.ProfilerDaemon` with the HTTP
   query plane enabled (``serve_port=0`` binds an ephemeral port);
3. poll ``/status`` and print ``profilerd top`` frames while ingestion is
   still streaming;
4. save ``/tree?fmt=html`` (interactive flamegraph), ``fmt=folded``
   (FlameGraph/speedscope interchange) and a library view, then shut down.

Run it::

    PYTHONPATH=src python examples/live_flamegraph.py

The equivalent from two shells, against a real job::

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --profile \\
        --backend daemon --spool /tmp/serve.spool        # terminal 1
    PYTHONPATH=src python -m repro.profilerd attach \\
        --spool /tmp/serve.spool --serve 8787            # terminal 2
    PYTHONPATH=src python -m repro.profilerd top --url http://127.0.0.1:8787
"""

import os
import sys
import tempfile
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.profilerd.agent import Agent  # noqa: E402
from repro.profilerd.daemon import DaemonConfig, ProfilerDaemon  # noqa: E402
from repro.profilerd.server import fetch_status, render_top  # noqa: E402


def tokenize(chunk):  # a recognizable hot path for the flamegraph
    return sum(len(w) for w in chunk.split())


def serve_request(n):
    total = 0
    for _ in range(200):
        total += tokenize("the quick brown fox " * 50)
    return total


def worker(stop):
    n = 0
    while not stop.is_set():
        serve_request(n)
        n += 1


def main() -> int:
    out = tempfile.mkdtemp(prefix="live_flamegraph_")
    spool = os.path.join(out, "job.spool")

    # 1. the "job": a busy worker thread + the raw-frame agent.
    stop = threading.Event()
    t = threading.Thread(target=worker, args=(stop,), name="serve-worker", daemon=True)
    t.start()
    agent = Agent(spool, period_s=0.02)
    agent.start()

    # 2. the observer: daemon + live HTTP query plane (out-of-process in real
    # deployments; a thread here so the example is one file).
    cfg = DaemonConfig(
        spool_path=spool,
        out_dir=os.path.join(out, "profile"),
        publish_interval_s=0.2,
        epoch_s=0.5,
        max_seconds=60,
        serve_port=0,
    )
    daemon = ProfilerDaemon(cfg)
    daemon.attach()
    server = daemon.enable_serving()
    runner = threading.Thread(target=daemon.run, daemon=True)
    runner.start()
    print(f"live query plane: {server.url}  (endpoints: /status /tree /timeline /diff)\n")

    # 3. watch it run: three `top` frames over the live HTTP API.
    for _ in range(3):
        time.sleep(1.0)
        print(render_top(fetch_status(server.url), server.url, k=5))
        print("-" * 72)

    # 4. export while still live: flamegraph HTML + folded stacks + a view.
    artifacts = {}
    for name, query in [
        ("flamegraph.html", "/tree?fmt=html"),
        ("profile.folded", "/tree?fmt=folded"),
        ("profile.speedscope.json", "/tree?fmt=speedscope"),
        ("host_threads.csv", "/tree?view=host_threads"),
    ]:
        path = os.path.join(out, name)
        with urllib.request.urlopen(server.url + query) as resp, open(path, "wb") as f:
            f.write(resp.read())
        artifacts[name] = path

    agent.stop()  # BYE -> the daemon drains, final-publishes and exits run()
    stop.set()
    runner.join(timeout=30)

    print("\nartifacts:")
    for name, path in artifacts.items():
        print(f"  {name:28s} {os.path.getsize(path):8d} bytes  {path}")
    print(f"\nopen {artifacts['flamegraph.html']} in a browser — click frames to zoom.")
    print("feed profile.folded to flamegraph.pl, or drop profile.speedscope.json")
    print("on speedscope; `python -m repro.profilerd serve --profile "
          f"{cfg.resolved_out_dir()}` re-serves this run offline.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
