"""Quickstart: train a tiny model with the profiling toolchain always on.

Runs a few steps of a reduced qwen3-4b on CPU, with:
  * the external host-plane sampler (the paper's perf_event analogue),
  * the device-plane HLO component tree of the compiled train step,
  * the dominance watchdog armed.

Prints both breakdowns and writes the interactive HTML report.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    DominanceDetector,
    Rule,
    SamplerConfig,
    StackSampler,
    WatchdogLoop,
    breakdown,
    tree_from_compiled,
    write_report,
)
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, cosine_schedule


def main(out_dir="/tmp/repro_quickstart", steps=8):
    cfg = get_config("qwen3-4b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw_init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
    step = jax.jit(
        make_train_step(model, cosine_schedule(3e-3, warmup_steps=2, total_steps=steps), AdamWConfig()),
        donate_argnums=(0, 1),
    )

    # --- profiling plane: external sampler + watchdog (zero instrumentation) ---
    sampler = StackSampler(SamplerConfig(period_s=0.05))
    detector = DominanceDetector([Rule(threshold=0.97, consecutive=3, min_window_total=8)])
    watchdog = WatchdogLoop(sampler, detector, interval_s=0.5)
    sampler.start()
    watchdog.start()

    # --- device plane: the compiled program IS the simulated architecture ----
    batch0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    compiled = step.lower(params, opt, batch0).compile()
    device_tree = tree_from_compiled(compiled)
    print("\n=== device-plane FLOPs breakdown (compiled train step) ===")
    for name, share in breakdown(device_tree, level=6, metric="flops", min_share=0.03):
        print(f"  {share:6.1%}  {name.split('/')[-1]}")

    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, metrics = step(params, opt, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f}")

    watchdog.stop()
    host_tree = sampler.stop()
    print("\n=== host-plane sample breakdown (external sampler) ===")
    for name, share in breakdown(host_tree, level=3, min_share=0.05):
        print(f"  {share:6.1%}  {name.split('/')[-1]}")
    paths = write_report(host_tree, out_dir, "host_profile")
    write_report(device_tree, out_dir, "device_profile", metric="flops")
    print(f"\ninteractive reports: {paths['html']} and {out_dir}/device_profile.html")
    print(f"anomalies: {[e.describe() for e in detector.events] or 'none'}")


if __name__ == "__main__":
    main()
