"""Fig. 13 analogue: injected livelock -> threshold detection -> checkpoint.

The paper injects a recycled mandatory-queue load into SLICC and shows the L1
breakdown degenerate to >90% load_hit, which the profiler flags and
checkpoints. Here we inject a spin into a worker mid-"training", and measure
detection latency (windows until the dominance rule fires) and that the
emergency checkpoint lands."""

from __future__ import annotations

import tempfile
import threading
import time

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import DominanceDetector, Rule, SamplerConfig, StackSampler

from .common import row


def injected_livelock_spin(stop):
    x = 0
    while not stop.is_set():
        x += 1


def main() -> list[str]:
    stop = threading.Event()
    worker = threading.Thread(target=injected_livelock_spin, args=(stop,), daemon=True)
    sampler = StackSampler(SamplerConfig(period_s=0.01))
    events = []
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        det = DominanceDetector(
            [Rule(pattern="injected_livelock_spin", threshold=0.2, min_window_total=4, self_only=False)],
        )
        det.add_callback(events.append)
        det.add_callback(
            lambda ev: ckpt.save_emergency(lambda: (0, {"state": np.zeros(4)}), ev)
        )
        sampler.start()
        t0 = time.perf_counter()
        worker.start()
        windows = 0
        detect_t = None
        while windows < 60 and detect_t is None:
            time.sleep(0.05)
            windows += 1
            if det.observe(sampler.snapshot()):
                detect_t = time.perf_counter() - t0
        sampler.stop()
        stop.set()
        worker.join()
        ok = bool(events) and ckpt.list_steps() == [0]
        share = events[0].share if events else 0.0
        return [
            row(
                "fig13_livelock_detect",
                (detect_t or 0.0) * 1e6,
                f"detected={ok};windows={windows};share={share:.2f};ckpt_tagged={ok}",
            )
        ]


if __name__ == "__main__":
    for r in main():
        print(r)
