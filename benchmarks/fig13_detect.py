"""Fig. 13 analogue: injected livelock -> threshold detection -> checkpoint.

The paper injects a recycled mandatory-queue load into SLICC and shows the L1
breakdown degenerate to >90% load_hit, which the profiler flags and
checkpoints.  This benchmark now runs the *production* detection path — the
fault corpus's ``injected_spin`` scenario under an out-of-process profilerd
(child target, mmap spool, daemon-side rules) — instead of an in-process
sampler, so the measured latency is the latency the deployed pipeline has:

  child spin -> agent spool -> daemon ingest -> dominance/trend verdict
  -> events.jsonl -> scoreboard ground-truth alignment -> ttd

The paper's warn+checkpoint flow is kept: the first scored verdict triggers
an emergency checkpoint tagged with the anomaly.
"""

from __future__ import annotations

import tempfile
from types import SimpleNamespace

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.faults import HarnessConfig, SCENARIOS, run_scenario, score_runs
from repro.faults.scoreboard import detector_of

from .common import row


def main() -> list[str]:
    cfg = HarnessConfig()
    res = run_scenario(SCENARIOS["injected_spin"], cfg, control=False)
    cells = score_runs(
        res.events,
        [],
        t_inject=res.t_inject,
        t_clear=res.t_clear,
        epoch_s=cfg.epoch_s,
        grace_epochs=cfg.grace_epochs,
    )
    dom = cells["dominance"]
    livelock = cells["trend_livelock"]

    # Paper §V-D: threshold violation -> emergency checkpoint tagged with the
    # anomaly.  Feed the first scored verdict into the real checkpoint path.
    scored = sorted(
        (ev for ev in res.events if detector_of(ev) is not None),
        key=lambda ev: ev.get("wall_time", 0.0),
    )
    ckpt_tagged = False
    if scored:
        first = scored[0]
        with tempfile.TemporaryDirectory() as d:
            ckpt = CheckpointManager(d)
            ckpt.save_emergency(
                lambda: (0, {"state": np.zeros(4)}),
                SimpleNamespace(
                    kind=first.get("kind", "?"),
                    path=tuple(first.get("path", ())),
                    share=float(first.get("share", 0.0)),
                ),
            )
            _, manifest = ckpt.restore(0)
            ckpt_tagged = manifest.get("tag") == "emergency"

    derived = (
        f"detected={dom.detected}"
        f";ttd_epochs={dom.ttd_epochs if dom.ttd_epochs is None else round(dom.ttd_epochs, 2)}"
        f";livelock_ttd_epochs="
        f"{livelock.ttd_epochs if livelock.ttd_epochs is None else round(livelock.ttd_epochs, 2)}"
        f";ckpt_tagged={ckpt_tagged}"
    )
    return [row("fig13_livelock_detect", (dom.ttd_s or 0.0) * 1e6, derived)]


if __name__ == "__main__":
    for r in main():
        print(r)
