"""Device-plane annotation overhead vs plain ingest throughput (ISSUE 6).

The merged plane must be effectively free for the daemon: every publish
window the daemon re-annotates the published tree with the device-plane cost
model (``repro.core.planes.annotate_tree`` — a host-tree copy, a name-index
lookup per node, and a two-pass attribute/occupy walk).  The acceptance
floor is **<5 % of ingest time** spent annotating at a realistic publish
cadence, on the same steady-state workload PR 2's ingest benchmark pinned
(depth 32, 95 % stack repetition, wire v2).

Methodology mirrors ``timeline_overhead.py``: publish windows are wall-clock
in the daemon, so the benchmark annotates at the *time-equivalent* cadence —
every ``plain_rate x window_s`` samples, i.e. the tree size a saturated
daemon would actually publish.  The device tree is built from the same
synthetic stacks (every shared-prefix frame plus a slice of the unique
tails carries HLO-shaped metrics), so the name matcher does representative
work instead of missing everything.

What is timed is the device plane's *marginal* cost, exactly as the daemon
pays it: the seal path builds a private fleet tree every epoch regardless
(that stand-in copy happens outside the timed region), then
``annotate_tree(tree, device, copy=False)`` annotates it in place.
Overhead is accounted **in-run**:

    overhead = total annotate time / (pass wall time - annotate - copy time)

i.e. annotation cost as a fraction of the pure ingest time in the same
measurement window — cross-run wall-clock subtraction on a shared runner is
noisier than the signal.

Results extend ``BENCH_ingest.json`` under an ``annotate_overhead`` key (the
PR 2 ingest results and later additions are preserved).

Usage::

  PYTHONPATH=src python benchmarks/annotate_overhead.py           # full run
  PYTHONPATH=src python benchmarks/annotate_overhead.py --smoke   # CI smoke

Pure stdlib + repro.core/profilerd (no jax).
"""

from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/annotate_overhead.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))

from ingest_throughput import encode_all, synth_samples, synth_stacks  # noqa: E402

from repro.core.calltree import CallTree  # noqa: E402
from repro.core.planes import OCCUPANCY, annotate_tree  # noqa: E402
from repro.profilerd.ingest import TreeIngestor  # noqa: E402
from repro.profilerd.wire import Decoder, RawSample  # noqa: E402

DEPTH = 32
REPEAT = 0.95
WINDOW_S = 1.0  # time-equivalent publish cadence (stricter than the daemon default)
CHUNK = 1 << 20
MATCH_FRACTION = 0.5  # unique tails that also exist on the device plane


def synth_device_tree(n: int) -> CallTree:
    """A device tree over the same frame names the ingested stream uses.

    Every shared-prefix frame matches (like ``named_scope``-tagged module
    code), plus ``MATCH_FRACTION`` of the unique tails (like jitted
    call-sites), each with HLO-shaped metrics.
    """
    rng = random.Random(1)
    n_unique = max(1, round(n * (1.0 - REPEAT)))
    tree = CallTree()
    for u, frames in enumerate(synth_stacks(DEPTH, n_unique, rng)):
        if u % max(1, int(1 / MATCH_FRACTION)) != 0:
            continue
        path = [f.func for f in frames] + ["dot"]
        tree.add_stack(
            path,
            {
                "ops": 3.0,
                "flops": rng.uniform(1e9, 1e12),
                "bytes": rng.uniform(1e6, 1e9),
                "coll_bytes": rng.uniform(0, 1e8),
            },
        )
    return tree


def run_once(payload: bytes, replays: int, annotate_every: int | None, device: CallTree | None):
    """Replay the stream through the daemon hot loop, annotating each window.

    Returns ``(seconds, ingestor, windows, annotate_seconds, copy_seconds)``
    where ``annotate_seconds`` is the wall time spent inside ``annotate_tree``
    and ``copy_seconds`` the (untimed-in-daemon) stand-in for the private
    fleet tree the seal path builds every epoch regardless.
    """
    clock = time.perf_counter
    ing = TreeIngestor()
    n = 0
    windows = 0
    ann_s = 0.0
    copy_s = 0.0
    merged = None
    next_mark = annotate_every if annotate_every else None
    t0 = clock()
    for _ in range(replays):
        dec = Decoder()  # a fresh attach per replay; samples re-intern cheaply
        for i in range(0, len(payload), CHUNK):
            for ev in dec.feed(payload[i : i + CHUNK]):
                if type(ev) is RawSample:
                    ing.ingest(ev)
                    n += 1
                    if device is not None and n == next_mark:
                        c0 = clock()
                        sealed = ing.tree.copy()  # the seal path's private tree
                        a0 = clock()
                        merged = annotate_tree(sealed, device, copy=False)
                        a1 = clock()
                        copy_s += a0 - c0
                        ann_s += a1 - a0
                        windows += 1
                        next_mark = n + annotate_every
    if device is not None:
        c0 = clock()
        sealed = ing.tree.copy()
        a0 = clock()
        merged = annotate_tree(sealed, device, copy=False)
        a1 = clock()
        copy_s += a0 - c0
        ann_s += a1 - a0
        windows += 1
        assert merged.root.metrics.get(OCCUPANCY, 0) > 0.99, "annotation produced no matches"
    dt = clock() - t0
    return dt, ing, windows, ann_s, copy_s


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny iteration counts (CI)")
    ap.add_argument("--samples", type=int, default=None, help="samples per replay")
    ap.add_argument("--replays", type=int, default=None, help="stream replays per pass")
    ap.add_argument("--annotate-every", type=int, default=None,
                    help="annotate every N samples (default: measured plain rate x 1s)")
    ap.add_argument("--out", default="BENCH_ingest.json")
    args = ap.parse_args(argv)
    n = args.samples or (800 if args.smoke else 40000)
    replays = args.replays or (2 if args.smoke else 16)
    reps = 1 if args.smoke else 3  # best-of: shared-runner wall clocks are noisy

    samples = synth_samples(DEPTH, REPEAT, n)
    payload = encode_all(samples, version=2)
    device = synth_device_tree(n)
    total = n * replays

    # Warmup pass (allocator, branch caches, interning).
    run_once(payload, 1, None, None)

    best_plain = float("inf")
    best_overhead = float("inf")
    annotated_stats = None
    annotate_every = args.annotate_every
    for _ in range(reps):
        dt, ing, _, _, _ = run_once(payload, replays, None, None)
        assert ing.tree.total() == total, "plain ingest lost samples"
        best_plain = min(best_plain, dt)
        if annotate_every is None:
            annotate_every = max(200, int(total / dt * WINDOW_S))

        dt, ing, windows, ann_s, copy_s = run_once(payload, replays, annotate_every, device)
        assert ing.tree.total() == total, "annotated ingest lost samples"
        # In-run accounting: annotation cost as a fraction of the pure
        # ingest time in the same pass (see module docstring).
        overhead = ann_s / max(dt - ann_s - copy_s, 1e-9)
        if overhead < best_overhead:
            best_overhead = overhead
            annotated_stats = (dt, windows, ann_s)
    plain_rate = total / best_plain
    annotated_dt, windows, ann_s = annotated_stats

    result = {
        "depth": DEPTH,
        "repeat": REPEAT,
        "n_samples": total,
        "window_equiv_s": WINDOW_S,
        "annotate_every": annotate_every,
        "windows": windows,
        "host_nodes": ing.tree.node_count(),
        "device_nodes": device.node_count(),
        "plain_ingest_s": round(best_plain, 6),
        "plain_per_s": round(plain_rate, 1),
        "annotated_pass_s": round(annotated_dt, 6),
        "annotate_s_total": round(ann_s, 6),
        "annotate_ms_per_window": round(ann_s / windows * 1000, 3),
        "overhead": round(best_overhead, 4),
        "smoke": args.smoke,
    }
    print(
        f"depth={DEPTH} repeat={REPEAT:.2f} n={total} "
        f"annotate_every={annotate_every} ({WINDOW_S:.0f}s-equivalent) windows={windows}\n"
        f"host nodes={result['host_nodes']} device nodes={result['device_nodes']}\n"
        f"plain ingest: {plain_rate:>12,.0f} samples/s\n"
        f"annotation  : {ann_s * 1000:.1f}ms total over {windows} windows "
        f"({result['annotate_ms_per_window']:.1f}ms/window)\n"
        f"overhead: {best_overhead:+.2%} of ingest time (floor: <5%)",
        flush=True,
    )

    # Extend BENCH_ingest.json in place, preserving earlier benchmark results.
    doc = {}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    doc["annotate_overhead"] = result
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")

    if args.smoke:
        print(f"[smoke] overhead {best_overhead:+.2%} (floor not enforced on tiny runs)")
        return 0
    ok = best_overhead < 0.05
    print(
        ("PASS " if ok else "FAIL ")
        + f"device-plane annotation overhead {best_overhead:+.2%} of ingest time (target <5%)"
    )
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
