"""Fig. 1 analogue: execution-engine comparison (AS/TS/O3 -> eager/blockwise/
compiled) for the same model, profiled by the same external sampler.

Reports tokens/host-second per engine plus the share of host samples spent in
jax dispatch frames — the "bookkeeping frames dominate" observation (paper
§II-B: ~20 pybind frames per gem5 stack <-> jax dispatch frames here). The
paper's counter-intuitive finding (the 'simpler' execution model is not
faster) reproduces as eager/blockwise trailing the fully-compiled engine
despite running identical math."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import BlockwiseEngine, CompiledEngine, EagerEngine, SamplerConfig, StackSampler
from repro.models import Model
from repro.models.modules import rms_norm
from repro.models.transformer import _ffn_kind, block_apply

from .common import row

B, S, STEPS = 2, 64, 3


def main() -> list[str]:
    cfg = get_config("qwen3-4b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def full_loss(p):
        return model.loss(p, batch)[0]

    # blockwise stages with REAL math: embed -> layer_j... -> head+CE
    def stage_embed(_):
        return jnp.take(params["embed"]["table"], tokens, axis=0).astype(jnp.bfloat16)

    def make_layer_stage(j):
        def stage(x):
            unit = jax.tree.map(lambda a: a[j], params["layers"]["scan"])
            h, _ = block_apply(unit["block0"], x, cfg, "attn", _ffn_kind(cfg, 0), positions, scope=f"layer{j}")
            return h

        return stage

    def stage_head(x):
        x = rms_norm(params["final_norm"], x, scope="final_norm")
        logits = model.logits_fn(params, x)
        lsm = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        return -jnp.take_along_axis(lsm, labels[..., None], -1).mean()

    n_units = params["layers"]["scan"]["block0"]["norm1"]["scale"].shape[0]
    stages = [stage_embed] + [make_layer_stage(j) for j in range(n_units)] + [stage_head]

    engines = [EagerEngine(full_loss), BlockwiseEngine(stages), CompiledEngine(full_loss)]
    out = []
    for eng in engines:
        sampler = StackSampler(SamplerConfig(period_s=0.02)).start()
        res = eng.run(STEPS, lambda i: (params,))
        tree = sampler.stop()
        total = max(tree.total(), 1)
        # share of samples whose *leaf* frame is jax-internal (dispatch etc.)
        jax_share = sum(
            n.self_metrics.get("samples", 0.0)
            for _, n in tree.root.walk()
            if n.name.startswith("jax::")
        ) / total
        tps = B * S * STEPS / res.wall_s
        out.append(row(
            f"fig01_engine_{eng.name}",
            res.wall_s / STEPS * 1e6,
            f"tokens_per_s={tps:.0f};jax_frame_share={jax_share:.2f}",
        ))
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
