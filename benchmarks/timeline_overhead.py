"""Epoch-sealing overhead vs plain ingest throughput (ISSUE 3).

The timeline ring must be effectively free for the daemon's hot loop: the
acceptance floor is **<5 % ingest-throughput overhead** for sealing epochs at
a realistic cadence, on the same workload PR 2's ingest benchmark pinned
(depth 32, 95 % stack repetition, wire v2).

What makes this hold is the counts fast path: the ingestor counts per-chain
hits as it ingests (one integer compare + add per sample), and the sealer
writes each epoch as a ``K_COUNTS`` record — two varints per *touched chain*,
never a tree walk (:class:`repro.core.snapshot.CountSealer`).  Keyframes
(segment rotation) snapshot the full tree and amortize over
``epochs_per_segment`` epochs.

Methodology: epochs are wall-clock in the daemon (default 5 s), so the
benchmark seals at the *time-equivalent* cadence — every
``plain_rate x epoch_s`` samples, i.e. what a saturated daemon would actually
ingest between two seals.  The workload replays the PR 2 steady-state stream
several times so multiple epochs (and a keyframe + path-definition record)
land mid-run.  The overhead is accounted **in-run**: every
``drain_epoch + seal`` block is timed inside the sealed pass, and

    overhead = total seal time / (pass wall time - total seal time)

i.e. sealing cost as a fraction of the pure ingest time *in the same
measurement window* — cross-run wall-clock subtraction on a shared runner
swings by far more than the signal.  The plain pass is still run and
reported (and compared against PR 2's recorded ingest rate) to confirm the
per-sample epoch bookkeeping added to ``TreeIngestor.ingest`` did not dent
base throughput.

Results extend ``BENCH_ingest.json`` under a ``timeline_overhead`` key (the
PR 2 ingest results are preserved).

Usage::

  PYTHONPATH=src python benchmarks/timeline_overhead.py           # full run
  PYTHONPATH=src python benchmarks/timeline_overhead.py --smoke   # CI smoke

Pure stdlib + repro.core/profilerd (no jax).
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):  # `python benchmarks/timeline_overhead.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.dirname(__file__))

from ingest_throughput import encode_all, synth_samples  # noqa: E402

from repro.core.snapshot import CountSealer, TimelineReader, TimelineWriter  # noqa: E402
from repro.profilerd.ingest import TreeIngestor  # noqa: E402
from repro.profilerd.wire import Decoder, RawSample  # noqa: E402

DEPTH = 32
REPEAT = 0.95
EPOCH_S = 1.0  # time-equivalent seal cadence (5x stricter than the daemon default)
CHUNK = 1 << 20


def run_once(payload: bytes, replays: int, epoch_every: int | None, timeline_dir: str | None):
    """Replay the stream ``replays`` times through the daemon hot loop.

    Seals every ``epoch_every`` samples when ``timeline_dir`` is set.
    Returns ``(seconds, ingestor, epochs_sealed, seal_seconds)`` where
    ``seal_seconds`` is the wall time spent inside ``drain_epoch + seal``.
    """
    clock = time.perf_counter
    ing = TreeIngestor()
    sealer = None
    writer = None
    if timeline_dir is not None:
        writer = TimelineWriter(timeline_dir)
        sealer = CountSealer(ing.tree, writer)
    n = 0
    epochs = 0
    seal_s = 0.0
    next_seal = epoch_every if epoch_every else None
    t0 = clock()
    for _ in range(replays):
        dec = Decoder()  # a fresh attach per replay; samples re-intern cheaply
        for i in range(0, len(payload), CHUNK):
            for ev in dec.feed(payload[i : i + CHUNK]):
                if type(ev) is RawSample:
                    ing.ingest(ev)
                    n += 1
                    if sealer is not None and n == next_seal:
                        s0 = clock()
                        entries, untracked = ing.drain_epoch()
                        sealer.seal(entries, wall_time=float(n), untracked=untracked)
                        seal_s += clock() - s0
                        epochs += 1
                        next_seal = n + epoch_every
    if sealer is not None:
        s0 = clock()
        entries, untracked = ing.drain_epoch()
        sealer.seal(entries, wall_time=float(n), untracked=untracked)
        writer.close()
        seal_s += clock() - s0
        epochs += 1
    dt = clock() - t0
    return dt, ing, epochs, seal_s


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny iteration counts (CI)")
    ap.add_argument("--samples", type=int, default=None, help="samples per replay")
    ap.add_argument("--replays", type=int, default=None, help="stream replays per pass")
    ap.add_argument("--epoch-every", type=int, default=None,
                    help="seal every N samples (default: measured plain rate x 1s)")
    ap.add_argument("--out", default="BENCH_ingest.json")
    args = ap.parse_args(argv)
    n = args.samples or (800 if args.smoke else 40000)
    replays = args.replays or (2 if args.smoke else 16)
    reps = 1 if args.smoke else 3  # best-of: shared-runner wall clocks are noisy

    samples = synth_samples(DEPTH, REPEAT, n)
    payload = encode_all(samples, version=2)
    total = n * replays

    # Warmup pass (allocator, branch caches, interning).
    run_once(payload, 1, None, None)

    # The epoch cadence comes from a steady-state plain measurement; the
    # plain pass also guards base throughput against PR 2's recorded rate.
    best_plain = float("inf")
    best_overhead = float("inf")
    sealed_stats = None
    epoch_every = args.epoch_every
    ring_bytes = 0
    for _ in range(reps):
        dt, ing, _, _ = run_once(payload, replays, None, None)
        assert ing.tree.total() == total, "plain ingest lost samples"
        best_plain = min(best_plain, dt)
        if epoch_every is None:
            epoch_every = max(200, int(total / dt * EPOCH_S))

        tl = tempfile.mkdtemp(prefix="bench-timeline-")
        try:
            dt, ing, epochs, seal_s = run_once(payload, replays, epoch_every, tl)
            assert ing.tree.total() == total, "sealed ingest lost samples"
            last = TimelineReader(tl).last()
            assert last is not None and last[1].root == ing.tree.root, (
                "timeline reconstruction diverged from the live tree"
            )
            # In-run accounting: sealing cost as a fraction of the pure
            # ingest time in the same pass (see module docstring).
            overhead = seal_s / max(dt - seal_s, 1e-9)
            if overhead < best_overhead:
                best_overhead = overhead
                ring_bytes = sum(
                    os.path.getsize(os.path.join(tl, f)) for f in os.listdir(tl)
                )
                sealed_stats = (dt, epochs, seal_s)
        finally:
            shutil.rmtree(tl, ignore_errors=True)
    plain_rate = total / best_plain
    sealed_dt, epochs, seal_s = sealed_stats

    result = {
        "depth": DEPTH,
        "repeat": REPEAT,
        "n_samples": total,
        "epoch_equiv_s": EPOCH_S,
        "epoch_every": epoch_every,
        "epochs_sealed": epochs,
        "plain_ingest_s": round(best_plain, 6),
        "plain_per_s": round(plain_rate, 1),
        "sealed_pass_s": round(sealed_dt, 6),
        "seal_s_total": round(seal_s, 6),
        "seal_ms_per_epoch": round(seal_s / epochs * 1000, 3),
        "overhead": round(best_overhead, 4),
        "ring_bytes": ring_bytes,
        "smoke": args.smoke,
    }
    print(
        f"depth={DEPTH} repeat={REPEAT:.2f} n={total} "
        f"epoch_every={epoch_every} ({EPOCH_S:.0f}s-equivalent) epochs={epochs}\n"
        f"plain ingest: {plain_rate:>12,.0f} samples/s\n"
        f"sealing     : {seal_s * 1000:.1f}ms total over {epochs} epochs "
        f"({result['seal_ms_per_epoch']:.1f}ms/epoch, {ring_bytes:,} ring bytes)\n"
        f"overhead: {best_overhead:+.2%} of ingest time (floor: <5%)",
        flush=True,
    )

    # Extend BENCH_ingest.json in place, preserving the PR 2 ingest results.
    doc = {}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    ref = None
    for r in doc.get("results", []):
        if r.get("depth") == DEPTH and r.get("repeat") == REPEAT and "v2" in r:
            ref = r["v2"].get("ingest_per_s")
    if ref:
        result["pr2_ref_ingest_per_s"] = ref
        print(
            f"base throughput vs PR 2 recorded v2 ingest: "
            f"{plain_rate:,.0f} vs {ref:,.0f} samples/s ({plain_rate / ref - 1:+.1%})"
        )
    doc["timeline_overhead"] = result
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")

    if args.smoke:
        print(f"[smoke] overhead {best_overhead:+.2%} (floor not enforced on tiny runs)")
        return 0
    ok = best_overhead < 0.05
    print(
        ("PASS " if ok else "FAIL ")
        + f"epoch sealing overhead {best_overhead:+.2%} of ingest time (target <5%)"
    )
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
