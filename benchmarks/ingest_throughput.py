"""Wire v1 vs v2 decode+ingest throughput and bytes/sample (ISSUE 2).

Steady-state simulator stacks repeat almost verbatim tick after tick — the
dominance pattern the paper exploits.  Wire v2 interns each unique stack once
(``STACKDEF``) and references it with a fixed-size ``SAMPLE2``; the daemon
resolves each ``(thread, stack_id)`` once and replays the cached
``CallNode`` chain as an O(depth) float-add loop.  This benchmark measures
both ends across synthetic stack depths and repeat ratios:

* ``bytes_per_sample`` — encoded spool bytes divided by sample count;
* ``ingest_per_s``     — decode + resolve + tree-merge samples/sec
  (``Decoder.feed`` -> ``TreeIngestor.ingest``, the daemon's hot loop).

Writes ``BENCH_ingest.json``.  Acceptance floor (depth 32, 95 % repetition):
v2 must show >= 5x ingest throughput and >= 4x fewer bytes than v1.

Usage::

  PYTHONPATH=src python benchmarks/ingest_throughput.py           # full run
  PYTHONPATH=src python benchmarks/ingest_throughput.py --smoke   # CI smoke

Pure stdlib + repro.core/profilerd (no jax), so it runs anywhere the test
suite runs.
"""

from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/ingest_throughput.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.profilerd.ingest import TreeIngestor
from repro.profilerd.wire import Decoder, Encoder, RawFrame, RawSample

DEPTHS = (8, 32, 128)
REPEATS = (0.5, 0.95)
TICK_SIZE = 4  # samples per encode_tick batch (threads per tick)


def synth_stacks(depth: int, n_unique: int, rng: random.Random) -> list[list[RawFrame]]:
    """Unique stacks sharing a realistic common root prefix (~3/4 of depth)."""
    shared = [
        RawFrame(f"/site-packages/jax/layer{i}.py", f"call_{i}", 10 + i)
        for i in range(max(1, depth * 3 // 4))
    ]
    stacks = []
    for u in range(n_unique):
        tail = [
            RawFrame(f"/root/repo/src/repro/mod{u % 7}.py", f"fn_{u}_{j}", rng.randrange(1, 500))
            for j in range(depth - len(shared))
        ]
        stacks.append(shared + tail)
    return stacks


def synth_samples(depth: int, repeat: float, n: int, seed: int = 0) -> list[RawSample]:
    """``n`` samples where a ``repeat`` fraction re-uses an already-seen stack.

    Re-drawn stacks get a jittered *leaf* line number, like a real thread
    actively executing inside its leaf function — interning must key on the
    (filename, func) sequence for the steady-state win to survive this.
    """
    rng = random.Random(seed)
    n_unique = max(1, round(n * (1.0 - repeat)))
    stacks = synth_stacks(depth, n_unique, rng)
    samples = []
    for i in range(n):
        # First occurrence of each unique stack is spread over the run; the
        # rest re-draw from stacks already introduced (steady-state pattern).
        introduced = max(1, min(n_unique, 1 + i * n_unique // n))
        u = rng.randrange(introduced)
        frames = stacks[u]
        leaf = frames[-1]
        frames = frames[:-1] + [RawFrame(leaf.filename, leaf.func, rng.randrange(1, 500))]
        # A stack belongs to the worker thread that executes it (threads
        # repeat their own stacks) — round-robin assignment would split each
        # stack across all threads and understate real cache locality.
        w = u % TICK_SIZE
        samples.append(RawSample(i * 0.01, 1000 + w, f"worker-{w}", frames))
    return samples


def encode_all(samples: list[RawSample], version: int) -> bytes:
    enc = Encoder(version=version)
    out = [enc.encode_hello(1234, 0.5)]
    for i in range(0, len(samples), TICK_SIZE):
        payload, _ = enc.encode_tick(samples[i : i + TICK_SIZE])
        out.append(payload)
    return b"".join(out)


def ingest_all(payload: bytes, chunk: int = 1 << 20) -> tuple[float, TreeIngestor]:
    """Feed the stream through the daemon's hot loop; returns (seconds, ingestor)."""
    dec = Decoder()
    ing = TreeIngestor()
    t0 = time.perf_counter()
    for i in range(0, len(payload), chunk):
        for ev in dec.feed(payload[i : i + chunk]):
            if type(ev) is RawSample:
                ing.ingest(ev)
    return time.perf_counter() - t0, ing


def bench_one(depth: int, repeat: float, n: int, reps: int) -> dict:
    samples = synth_samples(depth, repeat, n)
    out: dict = {"depth": depth, "repeat": repeat, "n_samples": n}
    for version in (1, 2):
        payload = encode_all(samples, version)
        best = float("inf")
        ing = None
        for _ in range(reps):
            dt, ing = ingest_all(payload)
            best = min(best, dt)
        assert ing is not None and ing.tree.total() == n, "ingest lost samples"
        out[f"v{version}"] = {
            "bytes": len(payload),
            "bytes_per_sample": round(len(payload) / n, 2),
            "ingest_s": round(best, 6),
            "ingest_per_s": round(n / best, 1),
            "fast_hits": ing.fast_hits,
        }
    out["speedup_ingest"] = round(out["v1"]["ingest_s"] / out["v2"]["ingest_s"], 2)
    out["bytes_ratio"] = round(out["v1"]["bytes"] / out["v2"]["bytes"], 2)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny iteration counts (CI)")
    ap.add_argument("--samples", type=int, default=None, help="samples per config")
    ap.add_argument("--out", default="BENCH_ingest.json")
    args = ap.parse_args(argv)
    n = args.samples or (800 if args.smoke else 40000)
    reps = 1 if args.smoke else 5  # best-of-5: shared-runner wall clocks are noisy

    results = []
    for depth in DEPTHS:
        for repeat in REPEATS:
            r = bench_one(depth, repeat, n, reps)
            results.append(r)
            print(
                f"depth={depth:<4d} repeat={repeat:.2f}  "
                f"v1={r['v1']['ingest_per_s']:>12,.0f}/s {r['v1']['bytes_per_sample']:>7.1f} B  "
                f"v2={r['v2']['ingest_per_s']:>12,.0f}/s {r['v2']['bytes_per_sample']:>7.1f} B  "
                f"speedup={r['speedup_ingest']:.2f}x bytes_ratio={r['bytes_ratio']:.2f}x",
                flush=True,
            )

    doc = {
        "bench": "ingest_throughput",
        "smoke": args.smoke,
        "n_samples": n,
        "tick_size": TICK_SIZE,
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")

    # Acceptance floor from the ISSUE (skipped in smoke mode: tiny runs are
    # timer-noise dominated; CI only checks the harness still runs).
    key = next(r for r in results if r["depth"] == 32 and r["repeat"] == 0.95)
    ok = key["speedup_ingest"] >= 5.0 and key["bytes_ratio"] >= 4.0
    msg = (
        f"depth32/95%: ingest speedup {key['speedup_ingest']}x (target >=5x), "
        f"bytes ratio {key['bytes_ratio']}x (target >=4x)"
    )
    if args.smoke:
        print(f"[smoke] {msg}")
        return 0
    print(("PASS " if ok else "FAIL ") + msg)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
