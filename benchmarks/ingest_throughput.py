"""Wire v1 vs v2 vs vectorized decode+ingest throughput (ISSUE 2 / ISSUE 8).

Steady-state simulator stacks repeat almost verbatim tick after tick — the
dominance pattern the paper exploits.  Wire v2 interns each unique stack once
(``STACKDEF``) and references it with a fixed-size ``SAMPLE2``; the daemon
resolves each ``(thread, stack_id)`` once and replays the cached
``CallNode`` chain as an O(depth) float-add loop.  The vectorized lane
(ISSUE 8) decodes whole ``SAMPLE2`` runs with one ``np.frombuffer``
structured view and collapses repeated samples to one batched add per
``(thread, stack)`` group.  This benchmark measures the whole trajectory
across synthetic stack depths and repeat ratios:

* ``bytes_per_sample`` — encoded spool bytes divided by sample count;
* ``ingest_per_s``     — decode + resolve + tree-merge samples/sec through
  ``IngestPipeline`` (the daemon's hot loop): ``v1``, ``v2`` (scalar
  per-sample), and ``vectorized`` (batch lane over the same v2 payload);
* ``*_steady``         — the *fast path* in isolation: every ``STACKDEF``
  already interned and every chain cached (a long-running simulator's
  steady state), so the stream is pure fixed-size ``SAMPLE2`` records.
  Whole-stream numbers share a cold floor — def decode + symbol resolve +
  path build for every unique stack — that both lanes pay identically and
  that the repeat ratio makes proportional to ``n``; the steady lanes
  measure what vectorization actually changes.

Writes ``BENCH_ingest.json`` (preserving sibling benchmarks' sections).
Acceptance floors (depth 32, 95 % repetition): v2 must show >= 5x ingest
throughput and >= 4x fewer bytes than v1, and the vectorized fast path must
show >= 5x throughput over the scalar v2 fast path (``speedup_fast_path``,
10x stretch).  The vectorized legs are skipped — reported as absent, never
as a failure — when numpy is missing, matching the pipeline's documented
scalar fallback.

Usage::

  PYTHONPATH=src python benchmarks/ingest_throughput.py           # full run
  PYTHONPATH=src python benchmarks/ingest_throughput.py --smoke   # CI smoke

Pure stdlib + repro.core/profilerd (no jax; numpy optional), so it runs
anywhere the test suite runs.
"""

from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/ingest_throughput.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.profilerd.pipeline import IngestPipeline
from repro.profilerd.wire import Encoder, RawFrame, RawSample, numpy_available

DEPTHS = (8, 32, 128)
REPEATS = (0.5, 0.95)
TICK_SIZE = 4  # samples per encode_tick batch (threads per tick)


def synth_stacks(depth: int, n_unique: int, rng: random.Random) -> list[list[RawFrame]]:
    """Unique stacks sharing a realistic common root prefix (~3/4 of depth)."""
    shared = [
        RawFrame(f"/site-packages/jax/layer{i}.py", f"call_{i}", 10 + i)
        for i in range(max(1, depth * 3 // 4))
    ]
    stacks = []
    for u in range(n_unique):
        tail = [
            RawFrame(f"/root/repo/src/repro/mod{u % 7}.py", f"fn_{u}_{j}", rng.randrange(1, 500))
            for j in range(depth - len(shared))
        ]
        stacks.append(shared + tail)
    return stacks


def synth_samples(depth: int, repeat: float, n: int, seed: int = 0) -> list[RawSample]:
    """``n`` samples where a ``repeat`` fraction re-uses an already-seen stack.

    Re-drawn stacks get a jittered *leaf* line number, like a real thread
    actively executing inside its leaf function — interning must key on the
    (filename, func) sequence for the steady-state win to survive this.
    """
    rng = random.Random(seed)
    n_unique = max(1, round(n * (1.0 - repeat)))
    stacks = synth_stacks(depth, n_unique, rng)
    samples = []
    for i in range(n):
        # First occurrence of each unique stack is spread over the run; the
        # rest re-draw from stacks already introduced (steady-state pattern).
        introduced = max(1, min(n_unique, 1 + i * n_unique // n))
        u = rng.randrange(introduced)
        frames = stacks[u]
        leaf = frames[-1]
        frames = frames[:-1] + [RawFrame(leaf.filename, leaf.func, rng.randrange(1, 500))]
        # A stack belongs to the worker thread that executes it (threads
        # repeat their own stacks) — round-robin assignment would split each
        # stack across all threads and understate real cache locality.
        w = u % TICK_SIZE
        samples.append(RawSample(i * 0.01, 1000 + w, f"worker-{w}", frames))
    return samples


def encode_all(samples: list[RawSample], version: int) -> bytes:
    enc = Encoder(version=version)
    out = [enc.encode_hello(1234, 0.5)]
    for i in range(0, len(samples), TICK_SIZE):
        payload, _ = enc.encode_tick(samples[i : i + TICK_SIZE])
        out.append(payload)
    return b"".join(out)


def encode_steady(samples: list[RawSample]) -> tuple[bytes, bytes]:
    """``(warm, steady)`` v2 payloads from one encoder: ``warm`` carries every
    STRDEF/STACKDEF; ``steady`` re-encodes the same samples against the warm
    intern tables, so it is pure fixed-size SAMPLE2 ticks."""
    enc = Encoder(version=2)
    warm = [enc.encode_hello(1234, 0.5)]
    for i in range(0, len(samples), TICK_SIZE):
        warm.append(enc.encode_tick(samples[i : i + TICK_SIZE])[0])
    steady = []
    for i in range(0, len(samples), TICK_SIZE):
        steady.append(enc.encode_tick(samples[i : i + TICK_SIZE])[0])
    return b"".join(warm), b"".join(steady)


def ingest_all(payload: bytes, vectorized: bool, chunk: int = 1 << 20) -> tuple[float, IngestPipeline]:
    """Feed the stream through the daemon's hot loop; returns (seconds, pipeline)."""
    pipe = IngestPipeline(vectorized=vectorized)
    t0 = time.perf_counter()
    for i in range(0, len(payload), chunk):
        pipe.feed(payload[i : i + chunk])
    return time.perf_counter() - t0, pipe


def _lane(payload: bytes, n: int, reps: int, vectorized: bool) -> dict:
    best = float("inf")
    pipe = None
    for _ in range(reps):
        dt, pipe = ingest_all(payload, vectorized)
        best = min(best, dt)
    assert pipe is not None and pipe.tree.total() == n, "ingest lost samples"
    return {
        "bytes": len(payload),
        "bytes_per_sample": round(len(payload) / n, 2),
        "ingest_s": round(best, 6),
        "ingest_per_s": round(n / best, 1),
        "fast_hits": pipe.ingestor.fast_hits,
        "vectorized": pipe.vectorized,
    }


def _steady_lane(warm: bytes, steady: bytes, n: int, reps: int, vectorized: bool) -> dict:
    """Fast-path throughput: warm the pipeline (defs interned, chains cached)
    untimed, then time the pure-SAMPLE2 steady stream."""
    pipe = IngestPipeline(vectorized=vectorized)
    chunk = 1 << 20
    for i in range(0, len(warm), chunk):
        pipe.feed(warm[i : i + chunk])
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for i in range(0, len(steady), chunk):
            pipe.feed(steady[i : i + chunk])
        best = min(best, time.perf_counter() - t0)
    assert pipe.tree.total() == n * (1 + reps), "steady ingest lost samples"
    return {
        "bytes": len(steady),
        "bytes_per_sample": round(len(steady) / n, 2),
        "ingest_s": round(best, 6),
        "ingest_per_s": round(n / best, 1),
        "vectorized": pipe.vectorized,
    }


def bench_one(depth: int, repeat: float, n: int, reps: int) -> dict:
    samples = synth_samples(depth, repeat, n)
    out: dict = {"depth": depth, "repeat": repeat, "n_samples": n}
    payload_v2 = None
    for version in (1, 2):
        payload = encode_all(samples, version)
        if version == 2:
            payload_v2 = payload
        out[f"v{version}"] = _lane(payload, n, reps, vectorized=False)
    out["speedup_ingest"] = round(out["v1"]["ingest_s"] / out["v2"]["ingest_s"], 2)
    out["bytes_ratio"] = round(out["v1"]["bytes"] / out["v2"]["bytes"], 2)
    warm, steady = encode_steady(samples)
    out["v2_steady"] = _steady_lane(warm, steady, n, reps, vectorized=False)
    if numpy_available():
        # Same v2 payload, batch lane: the v1 -> v2 -> vectorized trajectory.
        out["vectorized"] = _lane(payload_v2, n, reps, vectorized=True)
        out["speedup_vectorized"] = round(
            out["v2"]["ingest_s"] / out["vectorized"]["ingest_s"], 2
        )
        # The floor rides the fast path: both steady lanes start fully warm,
        # so the ratio isolates per-sample scalar work vs the batch lane.
        out["vectorized_steady"] = _steady_lane(warm, steady, n, reps, vectorized=True)
        out["speedup_fast_path"] = round(
            out["v2_steady"]["ingest_s"] / out["vectorized_steady"]["ingest_s"], 2
        )
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny iteration counts (CI)")
    ap.add_argument("--samples", type=int, default=None, help="samples per config")
    ap.add_argument("--out", default="BENCH_ingest.json")
    args = ap.parse_args(argv)
    n = args.samples or (800 if args.smoke else 40000)
    reps = 1 if args.smoke else 5  # best-of-5: shared-runner wall clocks are noisy

    results = []
    for depth in DEPTHS:
        for repeat in REPEATS:
            r = bench_one(depth, repeat, n, reps)
            results.append(r)
            vec = (
                f"vec={r['vectorized']['ingest_per_s']:>12,.0f}/s "
                f"({r['speedup_vectorized']:.2f}x stream, "
                f"{r['speedup_fast_path']:.2f}x fast path "
                f"{r['vectorized_steady']['ingest_per_s']:,.0f}/s)"
                if "vectorized" in r
                else "vec=unavailable (no numpy)"
            )
            print(
                f"depth={depth:<4d} repeat={repeat:.2f}  "
                f"v1={r['v1']['ingest_per_s']:>12,.0f}/s {r['v1']['bytes_per_sample']:>7.1f} B  "
                f"v2={r['v2']['ingest_per_s']:>12,.0f}/s {r['v2']['bytes_per_sample']:>7.1f} B  "
                f"speedup={r['speedup_ingest']:.2f}x bytes_ratio={r['bytes_ratio']:.2f}x  "
                + vec,
                flush=True,
            )

    # Sibling benchmarks (timeline_overhead, annotate_overhead) append their
    # sections to the same file; a refresh must not clobber them.
    doc = {}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    doc.update(
        {
            "bench": "ingest_throughput",
            "smoke": args.smoke,
            "n_samples": n,
            "tick_size": TICK_SIZE,
            "numpy": numpy_available(),
            "results": results,
        }
    )
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")

    # Acceptance floors from the ISSUEs (skipped in smoke mode: tiny runs are
    # timer-noise dominated; CI only checks the harness still runs).
    key = next(r for r in results if r["depth"] == 32 and r["repeat"] == 0.95)
    ok = key["speedup_ingest"] >= 5.0 and key["bytes_ratio"] >= 4.0
    msg = (
        f"depth32/95%: ingest speedup {key['speedup_ingest']}x (target >=5x), "
        f"bytes ratio {key['bytes_ratio']}x (target >=4x)"
    )
    if "vectorized" in key:
        ok = ok and key["speedup_fast_path"] >= 5.0
        msg += (
            f", vectorized fast path {key['speedup_fast_path']}x over scalar v2 "
            f"(target >=5x; whole stream {key['speedup_vectorized']}x)"
        )
    else:
        msg += ", vectorized lanes unavailable (no numpy)"
    if args.smoke:
        print(f"[smoke] {msg}")
        return 0
    print(("PASS " if ok else "FAIL ") + msg)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
