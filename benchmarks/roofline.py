"""§Roofline table: reads per-cell dry-run JSONs and emits the roofline CSV.

One row per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS, the useful-FLOPs ratio, the step-time bound and the
MFU at that bound. Sources preference: results/dryrun_optimized, falling back
to results/dryrun_baseline.
"""

from __future__ import annotations

import glob
import json
import os

from .common import row

RESULT_DIRS = ["results/dryrun_optimized", "results/dryrun_baseline"]


def load_cells() -> list[dict]:
    for d in RESULT_DIRS:
        files = sorted(glob.glob(os.path.join(d, "*.json")))
        if files:
            return [json.load(open(f)) for f in files]
    return []


def main() -> list[str]:
    cells = load_cells()
    out = []
    n_ok = n_skip = n_fail = 0
    worst = None
    for c in cells:
        if c["status"] == "skip":
            n_skip += 1
            continue
        if c["status"] != "ok":
            n_fail += 1
            continue
        n_ok += 1
        r = c["roofline"]
        mfu = r["mfu_bound"]
        if worst is None or mfu < worst[0]:
            worst = (mfu, c)
        out.append(
            row(
                f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}",
                r["t_step_s"] * 1e6,
                f"dominant={r['dominant']};t_comp={r['t_compute_s']:.3g};t_mem={r['t_memory_s']:.3g};"
                f"t_coll={r['t_collective_s']:.3g};mfu_bound={mfu:.3f};"
                f"useful_flops={r['useful_flops_ratio']:.2f};fits_hbm={r['fits_hbm']}",
            )
        )
    out.append(row("roofline_summary", 0.0, f"ok={n_ok};skip_by_rule={n_skip};fail={n_fail}"))
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
