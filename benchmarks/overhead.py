"""§V-E overhead claim: profiler overhead vs sampling period, per backend.

The paper claims 0.5 s sampling is 'negligible overhead' *because* profiling
runs out-of-process — the target pays only for frame capture.  We run a fixed
CPU workload unprofiled, then under both backends at 0.5s / 0.1s / 0.02s and
report the slowdown side by side:

* ``thread`` — in-process helper thread: capture + symbol resolution +
  classification + tree merging all burn target cycles;
* ``daemon`` — in-process raw-frame publisher only; resolution/merging/
  detection run in a separate ``repro.profilerd`` process.
"""

from __future__ import annotations

import os
import tempfile
import time

from repro.core import SamplerConfig, make_sampler

from .common import row


def workload(seconds=1.2):
    t0 = time.perf_counter()
    x = 0.0
    i = 0
    while time.perf_counter() - t0 < seconds:
        x += (i % 7) * 0.5
        i += 1
    return i


def _measure(backend: str, period: float, base: float) -> tuple[float, float, int]:
    cfg = SamplerConfig(period_s=period, backend=backend)
    if backend == "daemon":
        d = tempfile.mkdtemp(prefix="repro-overhead-")
        cfg = SamplerConfig(
            period_s=period, backend=backend, spool_path=os.path.join(d, "bench.spool"),
            spawn_daemon=True,
        )
    s = make_sampler(cfg)
    s.start()
    if hasattr(s, "wait_ready"):
        s.wait_ready()  # keep daemon start-up out of the steady-state number
    n = workload()
    s.stop()
    overhead = (base - n) / base
    return n / base, max(overhead, 0.0), s.n_samples


def main() -> list[str]:
    out = []
    base = workload()
    for period in (0.5, 0.1, 0.02):
        t_rel, t_ovh, t_n = _measure("thread", period, base)
        d_rel, d_ovh, d_n = _measure("daemon", period, base)
        out.append(
            row(
                f"overhead_period_{period}",
                period * 1e6,
                f"thread_overhead={t_ovh:.4f};daemon_overhead={d_ovh:.4f};"
                f"thread_iters_rel={t_rel:.4f};daemon_iters_rel={d_rel:.4f};"
                f"thread_samples={t_n};daemon_samples={d_n}",
            )
        )
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
