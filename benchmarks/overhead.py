"""§V-E overhead claim: sampler overhead vs sampling period.

The paper claims 0.5 s sampling is 'negligible overhead'. We run a fixed CPU
workload with no sampler and with samplers at 0.5s / 0.1s / 0.02s and report
the slowdown."""

from __future__ import annotations

import time

from repro.core import SamplerConfig, StackSampler

from .common import row


def workload(seconds=1.2):
    t0 = time.perf_counter()
    x = 0.0
    i = 0
    while time.perf_counter() - t0 < seconds:
        x += (i % 7) * 0.5
        i += 1
    return i


def main() -> list[str]:
    out = []
    base = workload()
    for period in (0.5, 0.1, 0.02):
        s = StackSampler(SamplerConfig(period_s=period))
        with s:
            n = workload()
        overhead = (base - n) / base
        out.append(
            row(
                f"overhead_period_{period}",
                period * 1e6,
                f"iters_rel={n/base:.4f};overhead={max(overhead,0):.4f};samples={s.n_samples}",
            )
        )
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
