"""Figs. 10/12 analogue: zoom-in views. The paper zooms from model-level
breakdowns into the L1/L2 cache controllers and the fetch stage; here the
same tree zooms into attention internals (qkv/rope/scores/pv/out) and MoE
internals (router/dispatch/experts/combine) — the views that localized the
§Perf fixes (e.g. the MoE combine all-reduce)."""

from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core import tree_from_compiled
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, cosine_schedule

from .common import row


def _shape(kind="train", gb=2, seq=32):
    return type("S", (), {"kind": kind, "global_batch": gb, "seq_len": seq})()


def main() -> list[str]:
    out = []
    # zoom 1: attention internals of a dense arch
    cfg = get_config("qwen3-4b", smoke=True)
    model = Model(cfg)
    compiled = (
        jax.jit(make_train_step(model, cosine_schedule(1e-3), AdamWConfig()))
        .lower(model.abstract_params(), jax.eval_shape(adamw_init, model.abstract_params()), model.input_specs(_shape()))
        .compile()
    )
    tree = tree_from_compiled(compiled)
    attn = tree.zoom("attention")
    total = max(attn.total("flops"), 1e-9)
    parts = []
    for sub in ("qkv_proj", "scores", "chunk_scores", "pv", "chunk_pv", "out_proj", "rope"):
        z = attn.zoom(sub)
        if z.total("flops") / total > 0.005:
            parts.append(f"{sub}={z.total('flops')/total:.2f}")
    out.append(row("fig10_zoom_attention_qwen3", 0.0, ";".join(parts)))

    # zoom 2: MoE internals
    cfg = get_config("deepseek-moe-16b", smoke=True)
    model = Model(cfg)
    compiled = (
        jax.jit(make_train_step(model, cosine_schedule(1e-3), AdamWConfig()))
        .lower(model.abstract_params(), jax.eval_shape(adamw_init, model.abstract_params()), model.input_specs(_shape()))
        .compile()
    )
    tree = tree_from_compiled(compiled)
    moe = tree.zoom(lambda n: n == "moe" or n == "moe_ep")
    total = max(moe.total("ops"), 1e-9)
    parts = []
    for sub in ("router", "dispatch", "experts", "combine", "shared_experts", "aux_loss"):
        z = moe.zoom(sub)
        if z.total("ops") / total > 0.005:
            parts.append(f"{sub}={z.total('ops')/total:.2f}")
    out.append(row("fig12_zoom_moe_deepseek", 0.0, ";".join(parts)))
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
