"""Fig. 2 analogue: sampled call-stack depth of the host runtime over a short
train run — the paper's observation that stack depth fluctuates heavily as
the runtime moves between dispatch, compute wait, and bookkeeping."""

from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core import SamplerConfig, StackSampler
from repro.data import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, cosine_schedule

from .common import row


def main() -> list[str]:
    import jax.numpy as jnp

    cfg = get_config("gemma-2b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw_init(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4))
    step = jax.jit(make_train_step(model, cosine_schedule(1e-3), AdamWConfig()), donate_argnums=(0, 1))
    sampler = StackSampler(SamplerConfig(period_s=0.01)).start()
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, _ = step(params, opt, batch)
    jax.block_until_ready(params)
    sampler.stop()
    trace = sampler.depth_trace()
    depths = [d for _, d in trace]
    if not depths:
        return [row("fig02_stack_depth", 0.0, "no-samples")]
    return [
        row(
            "fig02_stack_depth",
            float(len(trace)),
            f"min={min(depths)};max={max(depths)};mean={sum(depths)/len(depths):.1f};swing={max(depths)-min(depths)}",
        )
    ]


if __name__ == "__main__":
    for r in main():
        print(r)
