"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring for
the mapping to the paper's figures). Usage:

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig13      # substring filter
"""

from __future__ import annotations

import sys
import traceback

BENCHES = [
    ("fig01_engines", "benchmarks.fig01_engines"),
    ("fig02_stack_depth", "benchmarks.fig02_stack_depth"),
    ("fig08_11_breakdown", "benchmarks.fig08_11_breakdown"),
    ("fig10_12_zoom", "benchmarks.fig10_12_zoom"),
    ("fig13_detect", "benchmarks.fig13_detect"),
    ("overhead", "benchmarks.overhead"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    filt = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    failures = 0
    for name, modname in BENCHES:
        if filt and filt not in name:
            continue
        try:
            mod = __import__(modname, fromlist=["main"])
            for line in mod.main():
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
