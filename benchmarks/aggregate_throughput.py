"""Regional aggregator merge throughput at simulated fleet scale (ISSUE 9).

The fleet tier's hot loop is ``Aggregator.handle_push``: decode a CRC-framed
push body (the snapshot codec over HTTP), merge the delta into the node's
cumulative, and seal the node ring — all before the 200 goes out.  This
benchmark drives that loop directly (no sockets: the HTTP layer is
byte-shuffling around the same call) with pre-encoded bodies from simulated
node fleets, measuring:

* ``epochs_per_s``   — pushed epochs decoded + merged + sealed per second;
* ``bytes_per_epoch``— mean wire size of one epoch body (delta economy);
* ``fleet_seal_s``   — one fleet-wide merge + ring seal at that node count
  (the aggregator's per-``epoch_s`` background cost).

Each simulated node pushes a keyframe first, then deltas with a keyframe
every 16 epochs — the PushClient cadence — over stacks with a shared root
prefix and per-node tails, so merge cost scales the way a real region does.

Writes a ``fleet`` section into ``BENCH_ingest.json`` (preserving sibling
benchmarks' sections).  Acceptance floors (full runs only; smoke just checks
the harness): >= 300 epochs/s merged at 10 nodes and >= 250 epochs/s at 100
nodes (~1/3 of what this container measures, headroom for noisy shared
runners), with bytes_per_epoch <= 8 KiB at both scales.

Usage::

  PYTHONPATH=src python benchmarks/aggregate_throughput.py           # full
  PYTHONPATH=src python benchmarks/aggregate_throughput.py --smoke   # CI

Pure stdlib + repro.core/profilerd (no jax, no numpy), so it runs anywhere
the test suite runs.
"""

from __future__ import annotations

import argparse
import json
import os
import random
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):  # `python benchmarks/aggregate_throughput.py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.calltree import CallTree
from repro.core.snapshot import K_DELTA, K_FULL, EpochMeta
from repro.profilerd.aggregator import Aggregator, AggregatorConfig
from repro.profilerd.push import H_BOOT, H_EPOCH, H_INTERVAL, H_NODE, encode_push_body

NODE_COUNTS = (10, 100)
KEYFRAME_EVERY = 16  # PushClient's default cadence
DEPTH = 12
SITES_PER_EPOCH = 24  # distinct call sites a node's epoch window touches


def _epoch_window(node_i: int, epoch: int, rng: random.Random) -> CallTree:
    """One node-epoch of samples: shared framework prefix, per-node leaves."""
    t = CallTree()
    prefix = ["main", "train_loop", "step", f"shard_{node_i % 8}"]
    for s in range(SITES_PER_EPOCH):
        tail = [f"layer_{(epoch + s) % 16}", f"fn_{node_i}_{s % 6}"]
        path = (prefix + tail)[:DEPTH]
        t.add_stack(path, {"samples": float(1 + rng.randrange(4))})
    return t


def synth_fleet(n_nodes: int, n_epochs: int, seed: int = 0):
    """Pre-encoded push bodies: ``bodies[epoch][node] = (headers, body)``."""
    rng = random.Random(seed)
    cums = [CallTree() for _ in range(n_nodes)]
    bodies = []
    for e in range(n_epochs):
        row = []
        for i in range(n_nodes):
            window = _epoch_window(i, e, rng)
            cums[i].merge(window)
            if e % KEYFRAME_EVERY == 0:
                body = encode_push_body(K_FULL, EpochMeta(e, float(e)), cums[i])
            else:
                body = encode_push_body(K_DELTA, EpochMeta(e, float(e)), window)
            headers = {
                H_NODE: f"node-{i:03d}",
                H_BOOT: f"boot-{i}",
                H_EPOCH: str(e),
                H_INTERVAL: "5",
            }
            row.append((headers, body))
        bodies.append(row)
    expected = sum(c.total() for c in cums)
    return bodies, expected


def bench_one(n_nodes: int, n_epochs: int, reps: int) -> dict:
    bodies, expected = synth_fleet(n_nodes, n_epochs)
    n_bytes = sum(len(b) for row in bodies for _h, b in row)
    best = float("inf")
    best_seal = float("inf")
    for _ in range(reps):
        out_dir = tempfile.mkdtemp(prefix="repro-aggbench-")
        agg = Aggregator(AggregatorConfig(out_dir=out_dir, epochs_per_segment=64))
        try:
            t0 = time.perf_counter()
            for row in bodies:
                for headers, body in row:
                    code, _resp = agg.handle_push(headers, body)
                    assert code == 200, f"push refused: {code}"
            best = min(best, time.perf_counter() - t0)
            t0 = time.perf_counter()
            agg.seal_fleet_epoch(force=True)
            best_seal = min(best_seal, time.perf_counter() - t0)
            got = agg.fleet_tree().total()
            assert got == expected, f"mass lost: {got} != {expected}"
        finally:
            agg.close()
            shutil.rmtree(out_dir, ignore_errors=True)
    n_pushes = n_nodes * n_epochs
    return {
        "n_nodes": n_nodes,
        "n_epochs": n_epochs,
        "n_pushes": n_pushes,
        "wire_bytes": n_bytes,
        "bytes_per_epoch": round(n_bytes / n_pushes, 1),
        "merge_s": round(best, 6),
        "epochs_per_s": round(n_pushes / best, 1),
        "fleet_seal_s": round(best_seal, 6),
        "fleet_mass": expected,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny iteration counts (CI)")
    ap.add_argument("--epochs", type=int, default=None, help="epochs per node")
    ap.add_argument("--out", default="BENCH_ingest.json")
    args = ap.parse_args(argv)
    n_epochs = args.epochs or (4 if args.smoke else 48)
    reps = 1 if args.smoke else 3  # best-of-3: shared-runner wall clocks are noisy

    results = []
    for n_nodes in NODE_COUNTS:
        r = bench_one(n_nodes, n_epochs, reps)
        results.append(r)
        print(
            f"nodes={n_nodes:<4d} epochs={n_epochs:<4d}  "
            f"merge={r['epochs_per_s']:>10,.0f} epochs/s  "
            f"{r['bytes_per_epoch']:>8,.0f} B/epoch  "
            f"fleet_seal={r['fleet_seal_s'] * 1e3:.1f} ms",
            flush=True,
        )

    # Sibling benchmarks write their own sections to the same file; a
    # refresh must not clobber them.
    doc = {}
    if os.path.exists(args.out):
        try:
            with open(args.out) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    doc["fleet"] = {
        "bench": "aggregate_throughput",
        "smoke": args.smoke,
        "n_epochs": n_epochs,
        "keyframe_every": KEYFRAME_EVERY,
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out} (fleet section)")

    # Acceptance floors (skipped in smoke mode: tiny runs are timer-noise
    # dominated; CI smoke only checks the harness still runs end to end).
    floors = {10: 300.0, 100: 250.0}
    ok = True
    msgs = []
    for r in results:
        floor = floors[r["n_nodes"]]
        this_ok = r["epochs_per_s"] >= floor and r["bytes_per_epoch"] <= 8192
        ok = ok and this_ok
        msgs.append(
            f"{r['n_nodes']} nodes: {r['epochs_per_s']:,.0f} epochs/s "
            f"(floor {floor:,.0f}), {r['bytes_per_epoch']:,.0f} B/epoch (cap 8192)"
        )
    msg = "; ".join(msgs)
    if args.smoke:
        print(f"[smoke] {msg}")
        return 0
    print(("PASS " if ok else "FAIL ") + msg)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
