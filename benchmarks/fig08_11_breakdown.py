"""Figs. 8/9/11 analogue: per-architecture-family runtime decomposition.

The paper breaks gem5 runtime down per CPU model (AS/TS/O3) and finds the
breakdown *differentiates workloads* only when the model is detailed enough
(Obs. 1 vs Obs. 2). Here the device-plane tree decomposes the compiled train
step per component (attention / mlp / moe / recurrent / norms / lm_head /
optimizer) for one arch of each family — showing e.g. MoE archs dominated by
expert dispatch where dense archs are dominated by attention+mlp."""

from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core import tree_from_compiled
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, cosine_schedule

from .common import row

FAMILIES = ["qwen3-4b", "deepseek-moe-16b", "recurrentgemma-9b", "xlstm-125m"]
COMPONENTS = ["attention", "mlp", "moe", "rg_lru", "recurrent", "mlstm", "slstm", "lm_head", "embed", "optimizer"]


def main() -> list[str]:
    out = []
    for arch in FAMILIES:
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.abstract_params()
        opt = jax.eval_shape(adamw_init, params)
        batch = model.input_specs(type("S", (), {"kind": "train", "global_batch": 2, "seq_len": 32})())
        step = make_train_step(model, cosine_schedule(1e-3), AdamWConfig())
        compiled = jax.jit(step).lower(params, opt, batch).compile()
        tree = tree_from_compiled(compiled)
        total = max(tree.total("flops"), 1e-9)
        shares = []
        for comp in COMPONENTS:
            z = tree.zoom(lambda n, c=comp: n.startswith(c))
            s = z.total("flops") / total
            if s > 0.005:
                shares.append(f"{comp}={s:.2f}")
        out.append(row(f"fig08_11_breakdown_{arch}", 0.0, ";".join(shares)))
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
